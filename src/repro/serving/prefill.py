"""Chunked / paged prefill engine (the P side of PD disaggregation).

PrefillEngine processes prompts in fixed-size token chunks (jit'd once per
chunk bucket, cache threaded between chunks through LM.prefill_resume) and
schedules queued prompts shortest-remaining-first at chunk granularity, so a
short prompt never sits behind a long in-flight prefill. With a KVArena the
prefill phase is itself PAGED: each chunk reserves real KVPool blocks and
writes its KV straight into the per-layer block arenas through a per-task
block table (kernels/paged_prefill.py / paged_prefill_attention), so an
in-flight prompt pins blocks ∝ its length — never a dense max_len cache —
and a reservation the pool cannot serve DEFERS the task (backpressure)
instead of over-committing HBM. Completed prefixes land in a radix-backed
PrefixKVStore as refcounted block lists sized by real bytes: a later prompt
sharing an N-token prefix maps the entry's full blocks (copying only the
partial tail) and resumes prefill at token N.

Built through a `DevicePlacement`: every jit routes through its donate_jit
choke point, and the paged chunk jit pins the composed (private ∪ arena)
cache's PartitionSpec tree as out-shardings so the arena stays TP-sharded
through the donated write-back.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.proxy.params import GREEDY, SamplingParams, device_row
from repro.core.proxy.radix import RadixTree
from repro.models.lm import LM
from jax.sharding import PartitionSpec as P

from repro.models.stack import (_drop_entries, alloc_cache,
                                alloc_prefill_private_cache, full_attn_layer,
                                merge_arena_cache, split_arena_cache)
from repro.serving.arena import BlockHandoff, KVArena, _bucket, _pow2_floor
from repro.serving.kvpool import PrefixKVStore, _pytree_bytes
from repro.serving.placement import DevicePlacement
from repro.serving.sampling import sample_tokens


# ======================================================================
@dataclass
class PrefillTask:
    rid: int
    prompt: tuple
    cache: object = None              # threaded B=1 cache (None until started)
    logits: object = None             # last-token logits of the latest chunk
    cursor: int = 0                   # tokens resident (incl. reused prefix)
    reused: int = 0                   # prefix tokens resumed from the store
    snap: int = 0                     # snapshot boundary (shared-prefix hint)
    params: SamplingParams = GREEDY   # first-token decoding config
    t_start: float = 0.0
    compute_s: float = 0.0            # pure prefill compute (excl. queue wait)
    handoff: object = None            # BlockHandoff once finished (paged)

    @property
    def remaining(self) -> int:
        return len(self.prompt) - self.cursor


@dataclass
class PrefillResult:
    rid: int
    cache: object
    first_token: int
    prompt_len: int
    reused: int
    elapsed_s: float                  # prefill compute time (EWMA batch time)
    t_done: float = 0.0               # wall time the first token materialized


@dataclass
class PrefillEngine:
    _next_handoff_id = 0              # shared-pool-unique handoff keys
    lm: LM
    params: dict
    tables: Optional[dict]
    max_len: int
    chunk_tokens: int = 64            # target chunk size (TTFT/TPOT knob)
    enable_chunked: bool = True
    allow_partial_reuse: bool = True
    cache_cap: int = 32               # PrefixKVStore entries
    cache_cap_bytes: Optional[int] = None   # PrefixKVStore byte cap (LRU)
    tree: Optional[RadixTree] = None  # share the proxy's per-instance tree
    arena: Optional[KVArena] = None   # shared paged-KV runtime → paged mode
    block_size: int = 16              # accounting granularity (dense mode)
    placement: Optional[DevicePlacement] = None
    stats: dict = field(default_factory=lambda: {
        "prefills": 0, "cache_hits": 0, "prefix_hits": 0, "reused_tokens": 0,
        "tokens": 0, "chunks": 0, "busy_s": 0.0, "host_fetches": 0,
        "blocks_mapped": 0, "prefill_kv_peak_blocks": 0, "defers": 0})

    def __post_init__(self):
        if self.placement is None:
            self.placement = (self.arena.placement if self.arena is not None
                              else DevicePlacement.of(self.lm.mesh))
        pl = self.placement
        self._fn = pl.donate_jit(self._prefill)
        self._resume = pl.donate_jit(self._resume_impl, donate_argnums=(2,),
                                     static_argnums=(5,))
        self._first = pl.donate_jit(self._first_impl)
        self.queue: deque[PrefillTask] = deque()
        self._ready: list[PrefillResult] = []
        sup, limit = self.lm.chunked_prefill_support
        self.chunk = _pow2_floor(max(min(self.chunk_tokens, limit), 1))
        self.chunked = bool(self.enable_chunked and sup and self.chunk >= 8)
        # paged prefill rides the chunked machinery (blocks grow per chunk);
        # with chunking unsupported the engine falls back to dense prefill
        # and the decode engine's dense-scatter admission compat path
        self.paged = bool(self.arena is not None and self.chunked)
        if self.paged:
            self.block_size = self.arena.block_size
            cfg, plan = self.lm.cfg, self.lm.plan
            # pin the composed chunk output: private dense specs (full-attn
            # dropped) ∪ arena specs, with replicated last-token logits
            private = _drop_entries(
                cfg, plan, pl.dense_cache_specs(cfg, plan, 1, self.max_len),
                drop_full=True)
            merged = merge_arena_cache(cfg, plan, private,
                                       pl.arena_specs(cfg, plan,
                                                      quant=self.arena.quant))
            self._resume_paged = pl.donate_jit(
                self._resume_paged_impl, donate_argnums=(2,),
                out_specs=(merged, P()))
        self.store = PrefixKVStore(
            self.tree, self.cache_cap,
            pool=self.arena.pool if self.paged else None,
            capacity_bytes=self.cache_cap_bytes)
        if self.paged:
            self.arena.reclaimers.append(self.store.evict_for_blocks)

    # ---- jit bodies --------------------------------------------------
    def _prefill(self, params, tokens, true_len, tables):
        cache, logits, _ = self.lm.prefill(params, {"tokens": tokens},
                                           max_len=self.max_len, tables=tables,
                                           true_len=true_len)
        return cache, logits

    def _resume_impl(self, params, tokens, cache, chunk_len, tables,
                     attend_limit):
        cache, logits, _ = self.lm.prefill_resume(
            params, {"tokens": tokens}, cache, max_len=self.max_len,
            tables=tables, chunk_len=chunk_len, attend_limit=attend_limit)
        return cache, logits

    def _resume_paged_impl(self, params, tokens, cache, chunk_len, tables,
                           tbl_row):
        """One paged chunk: full-attention cache leaves are the shared
        arenas, the chunk's KV is written straight into the tabled blocks
        (no dense max_len cache exists anywhere on this path)."""
        cache, logits, _ = self.lm.prefill_resume(
            params, {"tokens": tokens}, cache, max_len=self.max_len,
            tables=tables, chunk_len=chunk_len, block_tables=tbl_row)
        return cache, logits

    def _first_impl(self, logits_tuple, temp, tk, tp, keys, fold):
        """Fused first-token sampling over the stacked last-token logits of
        a batch of finished prefills (pow2-padded)."""
        logits = jnp.concatenate(logits_tuple, axis=0)
        return sample_tokens(logits, temp, tk, tp, keys, fold)

    # ---- paged-KV helpers --------------------------------------------
    @staticmethod
    def _pf_key(rid: int) -> tuple:
        return ("prefill", rid)

    def _resize_full_attn(self, cache, length: int, copy_rest: bool = False):
        """Slice or zero-pad the full-attention KV leaves of a dense B=1
        cache to `length` tokens (the prefix-store sizing fix: stored
        prefixes pin prefix-length KV, not a max_len allocation). Ring /
        mamba leaves are untouched (bounded) unless copy_rest — then they
        are jnp.copy'd so the snapshot survives chunk-to-chunk donation."""
        cfg, plan = self.lm.cfg, self.lm.plan

        def one(spec, entry, stacked):
            if entry is None:
                return None
            if not full_attn_layer(cfg, spec):
                return jax.tree.map(jnp.copy, entry) if copy_rest else entry
            ax = 2 if stacked else 1

            def f(x):
                W = x.shape[ax]
                if W > length:
                    idx = [slice(None)] * x.ndim
                    idx[ax] = slice(0, length)
                    return x[tuple(idx)]
                if W < length:
                    pad = [(0, 0)] * x.ndim
                    pad[ax] = (0, length - W)
                    return jnp.pad(x, pad)
                return jnp.copy(x) if copy_rest else x
            return {kk: f(vv) for kk, vv in entry.items()}

        return {"period": tuple(one(s, cache["period"][i], True)
                                for i, s in enumerate(plan.period)),
                "rem": tuple(one(s, cache["rem"][i], False)
                             for i, s in enumerate(plan.rem)),
                "pos": jnp.copy(cache["pos"]) if copy_rest else cache["pos"]}

    def _grow_blocks(self, task: PrefillTask, cl: int) -> bool:
        """Reserve pool blocks for the next `cl` chunk tokens. On
        exhaustion, reclaim shared cache (LRU store entries) and retry;
        still short → False (the caller defers this task — backpressure
        instead of HBM over-commit)."""
        pool, key = self.arena.pool, self._pf_key(task.rid)
        target = task.cursor + cl

        def attempt():
            if key in pool:
                return pool.extend(key, task.cursor, target)
            return pool.allocate(key, target)

        got = attempt()
        if got is None:
            held = len(pool.owned(key)) if key in pool else 0
            need = pool.blocks_for(target) - held - pool.free_blocks
            self.arena.reclaim(max(need, 1))
            got = attempt()
        return got is not None

    def _table_row(self, rid: int) -> jnp.ndarray:
        nb = -(-self.max_len // self.block_size)
        row = np.zeros((1, nb), np.int32)
        owned = self.arena.pool.owned(self._pf_key(rid))
        row[0, :len(owned)] = owned
        return jnp.asarray(row)

    def _store_put_paged(self, task: PrefillTask, n: int,
                         copy_private: bool) -> None:
        """Publish the first `n` tokens of a task as a store entry: the
        covering blocks are adopted (refcounted) by the store — zero copy —
        and only the bounded private leaves are snapshotted. Entry size is
        the REAL resident bytes, so LRU eviction can tell a 16-token prefix
        from a 2048-token one."""
        pool = self.arena.pool
        blocks = pool.owned(self._pf_key(task.rid))[:pool.blocks_for(n)]
        priv = jax.tree.map(jnp.copy, task.cache) if copy_private \
            else task.cache
        nbytes = (len(blocks) * self.arena.block_nbytes + _pytree_bytes(priv)
                  + _pytree_bytes(task.logits))
        self.store.put(task.prompt[:n], priv, task.logits, blocks=blocks,
                       nbytes=nbytes)

    def _release_result(self, rec: PrefillResult) -> None:
        """Drop an undelivered result (supersede/abort): a paged handoff
        still owns pool blocks that nobody will ever admit."""
        if isinstance(rec.cache, BlockHandoff):
            self.arena.pool.release(rec.cache.key)

    def _note_peak(self, task: PrefillTask) -> None:
        """Work-based memory metric: peak KV blocks pinned by a SINGLE
        in-flight prefill. Paged tasks grow per chunk, so the peak is
        blocks_for(prompt_len); a dense task pins a blocks_for(max_len)
        cache from its first chunk regardless of prompt length — exactly
        the prefill-phase over-commit paged prefill removes."""
        if self.paged:
            held = len(self.arena.pool.owned(self._pf_key(task.rid)))
        else:
            held = -(-self.max_len // self.block_size)
        if held > self.stats["prefill_kv_peak_blocks"]:
            self.stats["prefill_kv_peak_blocks"] = held

    # ---- scheduling --------------------------------------------------
    def start(self, rid: int, prompt: tuple, prefix_hint: int = 0,
              params: Optional[SamplingParams] = None) -> None:
        """Enqueue a prompt. Exact store hits complete immediately (drained
        by the next step()); partial hits resume at the stored boundary.
        prefix_hint (the proxy's Match_P, computed before self-insertion)
        marks a prefix shared with other prompts: the engine snapshots its
        cache at that boundary so later sharers can resume there."""
        # a re-dispatch of the same rid (instance fail/recover) supersedes any
        # queued task or undelivered result — otherwise both complete and the
        # proxy sees duplicate first tokens
        for t in list(self.queue):
            if t.rid == rid:
                self.queue.remove(t)
                if self.paged:
                    self.arena.pool.release(self._pf_key(rid))
        for r in self._ready:
            if r.rid == rid:
                self._release_result(r)
        self._ready = [r for r in self._ready if r.rid != rid]
        task = PrefillTask(rid, tuple(prompt), params=params or GREEDY,
                           t_start=time.monotonic())
        if (self.chunked and self.allow_partial_reuse
                and 8 <= prefix_hint < len(task.prompt)):
            task.snap = prefix_hint
        self._try_resume(task)
        self.queue.append(task)

    def _try_resume(self, task: PrefillTask) -> None:
        """Resume from the deepest stored prefix (exact hits: adopt whole)."""
        if self.paged:
            self._try_resume_paged(task)
            return
        n, cache, logits = self.store.lookup(task.prompt)
        if cache is None or n <= task.cursor:
            return
        if n == len(task.prompt):
            # stored caches are prefix-trimmed: pad the full-attention KV
            # back to the engine's max_len working shape (ring/mamba leaves
            # are shared — an adopted whole is never donated downstream)
            task.cache, task.logits = \
                self._resize_full_attn(cache, self.max_len), logits
            task.cursor = task.reused = n
            return
        if self.chunked and self.allow_partial_reuse:
            # copy — the threaded cache is donated chunk-to-chunk and must
            # not eat the store's buffers
            task.cache = self._resize_full_attn(cache, self.max_len,
                                                copy_rest=True)
            task.logits = logits
            task.cursor = task.reused = n
            self.stats["prefix_hits"] += 1
            self.stats["reused_tokens"] += n

    def _try_resume_paged(self, task: PrefillTask) -> None:
        """Paged resume: map the entry's FULL prefix blocks into the task's
        table (refcount++, zero copy); a partial tail block is copied into
        a private block — its content diverges as the task appends. Exact
        hits adopt the same way (the tail copy keeps two adopters of one
        prompt from clobbering each other's decode-time appends)."""
        ent = self.store.lookup_entry(task.prompt)
        if ent is None or ent.n <= task.cursor or ent.blocks is None:
            return
        if not (self.allow_partial_reuse or ent.n == len(task.prompt)):
            return
        pool, key = self.arena.pool, self._pf_key(task.rid)
        if key in pool:                 # mid-flight deepening is unsound
            return
        n = ent.n
        full = n // pool.block_size
        # pin the entry's blocks for the duration: reclaim-under-pressure
        # below may evict THIS entry, and without the pin its released
        # blocks would hit the free list while we are about to map them as
        # `shared` (and read the tail for the copy) — allocator corruption
        pin = ("resume-pin", task.rid)
        pool.adopt(pin, ent.blocks)
        try:
            tbl = pool.allocate(key, n, shared=ent.blocks[:full])
            if tbl is None:
                self.arena.reclaim(pool.blocks_for(n) - full)
                tbl = pool.allocate(key, n, shared=ent.blocks[:full])
                if tbl is None:
                    return              # backpressure: prefill from scratch
            if pool.blocks_for(n) > full:   # partial tail → copy-on-write
                self.arena.copy_block(ent.blocks[full], tbl[full])
        finally:
            pool.release(pin)
        # private leaves are donated chunk-to-chunk: always copy
        task.cache = jax.tree.map(jnp.copy, ent.cache)
        task.logits = ent.logits
        task.cursor = task.reused = n
        self.stats["blocks_mapped"] += full
        if n < len(task.prompt):
            self.stats["prefix_hits"] += 1
            self.stats["reused_tokens"] += n

    def has_work(self) -> bool:
        return bool(self.queue or self._ready)

    def abort(self, rid: int) -> bool:
        """Drop a queued / in-flight / completed-but-undelivered prompt.
        The task's private cache is released to the GC and its pool blocks
        (paged) are released; store snapshots it already published stay —
        they are shared cache, not request state (their blocks are
        refcounted under the store's own key)."""
        hit = False
        for t in list(self.queue):
            if t.rid == rid:
                self.queue.remove(t)
                hit = True
        if self.paged:
            self.arena.pool.release(self._pf_key(rid))
        n0 = len(self._ready)
        for r in self._ready:
            if r.rid == rid:
                self._release_result(r)
        self._ready = [r for r in self._ready if r.rid != rid]
        return hit or len(self._ready) != n0

    def drop_results(self) -> int:
        """Discard every completed-but-undelivered result, releasing paged
        handoff blocks (instance-death recovery: a dead engine's results
        will never be drained by the server loop — without this their
        ("handoff", i) pool keys leak). → results dropped."""
        n = len(self._ready)
        for r in self._ready:
            self._release_result(r)
        self._ready = []
        return n

    def step(self, token_budget: int = 1 << 30) -> list[PrefillResult]:
        """Run up to `token_budget` tokens of prefill work; → completed
        prompts. Chunked mode schedules shortest-remaining-first at chunk
        granularity (a short prompt preempts an in-flight long prefill at
        the next chunk boundary); unchunked mode is the pre-chunking engine:
        FIFO, one whole prompt per call. Paged tasks that cannot grow their
        block reservation are DEFERRED for the round (stats.defers) rather
        than over-committing — they retry when decode/store releases free
        blocks."""
        done, budget = self._ready, token_budget
        self._ready = []
        fresh: list[PrefillTask] = []
        blocked: set[int] = set()
        t0 = time.monotonic()
        while budget > 0:
            cands = [t for t in self.queue if t.rid not in blocked]
            if not cands:
                break
            task = (min(cands, key=lambda t: t.remaining)
                    if self.chunked else cands[0])
            if task.cursor == 0:
                # entries stored since enqueue (e.g. a queued sharer's
                # snapshot) are visible to tasks that have not started
                self._try_resume(task)
            if task.remaining > 0:
                ran = (self._run_chunk(task, min(budget, self.chunk))
                       if self.chunked else self._run_full(task))
                if ran == 0 and task.remaining > 0:
                    blocked.add(task.rid)       # pool backpressure: defer
                    continue
                budget -= ran
            if task.remaining == 0:
                self.queue.remove(task)
                fresh.append(self._finish(task))
        if fresh:
            done.extend(self._emit(fresh))
        self.stats["busy_s"] += time.monotonic() - t0
        return done

    def _run_chunk(self, task: PrefillTask, budget: int) -> int:
        t0 = time.monotonic()
        cl = min(self.chunk, task.remaining, max(budget, 1))
        if task.cursor < task.snap:
            cl = min(cl, task.snap - task.cursor)   # land on the boundary
        if self.paged and not self._grow_blocks(task, cl):
            self.stats["defers"] += 1
            return 0
        if task.cache is None:
            task.cache = (alloc_prefill_private_cache(
                self.lm.cfg, self.lm.mesh, self.lm.plan, self.max_len)
                if self.paged else
                alloc_cache(self.lm.cfg, self.lm.mesh, self.lm.plan, 1,
                            self.max_len))
        S = min(_bucket(cl, lo=8), self.chunk)
        toks = list(task.prompt[task.cursor:task.cursor + cl]) + [0] * (S - cl)
        if self.paged:
            # chunk KV is written straight into the arena blocks through
            # the task's table — the composed cache's full-attention leaves
            # ARE the shared arenas (donated and written back)
            composed = merge_arena_cache(self.lm.cfg, self.lm.plan,
                                         task.cache, self.arena.kv)
            composed, task.logits = self._resume_paged(
                self.params, jnp.asarray([toks], jnp.int32), composed,
                jnp.int32(cl), self.tables, self._table_row(task.rid))
            task.cache, self.arena.kv = split_arena_cache(
                self.lm.cfg, self.lm.plan, composed)
        else:
            # attend_limit=0: one trace per chunk bucket. (Passing a pow2
            # prefix bound trims attention flops but multiplies trace
            # count — a win on accelerators, a compile-stall hazard on the
            # CPU-real path.)
            task.cache, task.logits = self._resume(
                self.params, jnp.asarray([toks], jnp.int32), task.cache,
                jnp.int32(cl), self.tables, 0)
        task.cursor += cl
        self.stats["tokens"] += cl
        self.stats["chunks"] += 1
        self._note_peak(task)
        if task.cursor == task.snap:
            shared = task.prompt[:task.snap]
            if self.store.lookup(shared)[0] != task.snap:
                if self.paged:
                    self._store_put_paged(task, task.snap, copy_private=True)
                else:
                    # prefix-length snapshot (sizing fix): slice the
                    # full-attention KV to the boundary instead of pinning
                    # a max_len copy
                    self.store.put(
                        shared,
                        self._resize_full_attn(
                            task.cache,
                            min(_bucket(task.snap, lo=8), self.max_len),
                            copy_rest=True),
                        task.logits)
        task.compute_s += time.monotonic() - t0
        return cl

    def _run_full(self, task: PrefillTask) -> int:
        t0 = time.monotonic()
        S = len(task.prompt)
        # lo=8: same bucket floor as the chunked path — a short prompt must
        # not compile a gratuitous extra trace just because it arrived at
        # an unchunked engine
        pad = min(_bucket(S, lo=8), self.max_len) - S
        toks = jnp.asarray([list(task.prompt) + [0] * pad], jnp.int32)
        task.cache, task.logits = self._fn(self.params, toks, jnp.int32(S),
                                           self.tables)
        task.cursor = S
        self.stats["tokens"] += S
        self._note_peak(task)
        task.compute_s += time.monotonic() - t0
        return S

    def _finish(self, task: PrefillTask) -> PrefillTask:
        """Store bookkeeping for a completed prompt. The first token is NOT
        sampled here: finished tasks of one engine round are sampled in a
        single fused call (`_emit`) — the per-record `int(jnp.argmax(...))`
        host sync is gone. Paged tasks turn into a BlockHandoff: pool
        ownership moves from the task to the handoff record, which
        admission later renames to the decode rid — zero copy end to end."""
        L = len(task.prompt)
        if task.reused == L:                    # whole prompt adopted
            self.stats["cache_hits"] += 1
        else:
            self.stats["prefills"] += 1
            if self.paged:
                self._store_put_paged(task, L, copy_private=False)
            else:
                self.store.put(
                    task.prompt,
                    self._resize_full_attn(
                        task.cache, min(_bucket(L, lo=8), self.max_len)),
                    task.logits)
        if self.paged:
            pool, key = self.arena.pool, self._pf_key(task.rid)
            # class-level counter: several engines share one pool (arena),
            # so handoff keys must be unique ACROSS engines — per-engine
            # counters collide at ("handoff", 0)
            hkey = ("handoff", PrefillEngine._next_handoff_id)
            PrefillEngine._next_handoff_id += 1
            blocks = tuple(pool.transfer(key, hkey))
            task.handoff = BlockHandoff(hkey, blocks, task.cache, L)
        return task

    def _emit(self, tasks: list) -> list[PrefillResult]:
        toks = self.sample_first([t.logits for t in tasks],
                                 [t.params for t in tasks],
                                 [t.rid for t in tasks],
                                 [len(t.prompt) for t in tasks])
        t_done = time.monotonic()
        return [PrefillResult(t.rid, t.handoff if t.handoff is not None
                              else t.cache, int(tok), len(t.prompt),
                              t.reused, t.compute_s, t_done)
                for t, tok in zip(tasks, toks)]

    def sample_first(self, logits_list, params_list, rids, folds
                     ) -> np.ndarray:
        """Sample the first token for a batch of finished prompts under
        each one's SamplingParams in ONE jit call + ONE host fetch
        (pow2-padded to bound retraces). logits_list: [1, V] arrays;
        folds: context lengths (= prompt lengths)."""
        n = len(logits_list)
        npad = _bucket(n, lo=1)
        logits = tuple(logits_list) + (logits_list[-1],) * (npad - n)
        rows = [device_row(p, r) for p, r in zip(params_list, rids)]
        rows += [rows[-1]] * (npad - n)
        temp = jnp.asarray([r[0] for r in rows], jnp.float32)
        tk = jnp.asarray([r[1] for r in rows], jnp.int32)
        tp = jnp.asarray([r[2] for r in rows], jnp.float32)
        keys = jnp.asarray(np.stack([r[3] for r in rows]))
        fold = jnp.asarray(list(folds) + [folds[-1]] * (npad - n), jnp.int32)
        out = np.asarray(self._first(logits, temp, tk, tp, keys, fold))
        self.stats["host_fetches"] += 1
        return out[:n]

    # ---- blocking back-compat API ------------------------------------
    def process(self, prompt: tuple) -> tuple:
        """→ (cache B=1, first_token:int, elapsed_s). Runs the prompt to
        completion (chunked underneath when supported)."""
        t0 = time.monotonic()
        self.start(-1, tuple(prompt))
        while True:
            recs = self.step()
            self._ready.extend(r for r in recs if r.rid != -1)
            for rec in recs:
                if rec.rid == -1:
                    return rec.cache, rec.first_token, time.monotonic() - t0
