"""Quickstart: build a reduced model, run the full OmniInfer serving stack
(OmniProxy → prefill → KV transfer → batched decode with sink+recent
compressed caches) on CPU, print serving metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import reduced_config
from repro.core.proxy import OASConfig
from repro.serving import Server, ServerConfig


def main():
    cfg = reduced_config("qwen2-1.5b")
    print(f"arch={cfg.arch_id} (reduced: {cfg.n_layers}L d{cfg.d_model}) "
          f"compression pattern={cfg.default_compression_pattern()}")

    srv = Server(cfg, ServerConfig(n_prefill=1, n_decode=1, decode_slots=4,
                                   max_len=96,
                                   oas=OASConfig(defer_window=0.0)))
    rng = np.random.default_rng(0)
    shared = tuple(rng.integers(0, 500, 16).tolist())   # shared system prompt
    requests = []
    for i in range(6):
        prompt = shared + tuple(rng.integers(0, 500, 4 + 3 * i).tolist()) \
            if i % 2 == 0 else \
            tuple(rng.integers(0, 500, int(rng.integers(8, 24))).tolist())
        requests.append((prompt, 6))

    summary = srv.run(requests, max_wall_s=180)
    print(f"\nserved {summary['n_done']} requests in {summary['wall_s']:.1f}s")
    print(f"  QPM        {summary['qpm']:.1f}")
    print(f"  TTFT mean  {summary['ttft_mean']*1e3:.0f} ms")
    print(f"  TPOT mean  {summary['tpot_mean_ms']:.0f} ms")
    hits = sum(e['cache_hits'] for e in summary['prefill_stats'])
    print(f"  APC hits   {hits}")
    kv = sum(e['kv_transfer_bytes'] for e in summary['decode_stats'])
    print(f"  P→D KV transferred {kv/1e6:.2f} MB")


if __name__ == "__main__":
    main()
