"""Quickstart: build a reduced model, stream requests through the full
OmniInfer serving stack (OmniProxy → chunked prefill → KV transfer → batched
decode with per-request sampling) via the `generate()` iterator, print
serving metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import time

import numpy as np

from repro.configs import reduced_config
from repro.core.proxy import OASConfig
from repro.serving import SamplingParams, Server, ServerConfig


def main():
    smoke = bool(os.environ.get("REPRO_SMOKE"))    # CI: tiny, fast config
    cfg = reduced_config("qwen2-1.5b")
    if smoke:
        cfg = cfg.with_updates(n_layers=2)
    print(f"arch={cfg.arch_id} (reduced: {cfg.n_layers}L d{cfg.d_model}) "
          f"compression pattern={cfg.default_compression_pattern()}")

    srv = Server(cfg, ServerConfig(n_prefill=1, n_decode=1, decode_slots=4,
                                   max_len=96,
                                   oas=OASConfig(defer_window=0.0)))
    rng = np.random.default_rng(0)
    shared = tuple(rng.integers(0, 500, 16).tolist())   # shared system prompt
    prompts, params = [], []
    for i in range(3 if smoke else 6):
        prompt = shared + tuple(rng.integers(0, 500, 4 + 3 * i).tolist()) \
            if i % 2 == 0 else \
            tuple(rng.integers(0, 500, int(rng.integers(8, 24))).tolist())
        prompts.append(prompt)
        # every request carries its own decoding config: even rids greedy,
        # odd rids seeded temperature sampling
        params.append(SamplingParams(max_tokens=6) if i % 2 == 0 else
                      SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                                     seed=i, max_tokens=6))

    t0 = time.monotonic()
    streamed: dict[int, list] = {}
    for out in srv.generate(prompts, params, max_wall_s=180):
        streamed.setdefault(out.rid, []).extend(out.new_tokens)
        if out.finished:
            print(f"  rid {out.rid}: {out.n_generated} tokens "
                  f"({out.finish_reason})  {streamed[out.rid]}")
    wall = time.monotonic() - t0

    summary = srv.metrics.summary(wall)
    print(f"\nserved {summary['n_done']} requests in {wall:.1f}s "
          f"(stop={summary['n_stop']} length={summary['n_length']} "
          f"aborted={summary['n_aborted']})")
    print(f"  QPM        {summary['qpm']:.1f}")
    print(f"  TTFT mean  {summary['ttft_mean']*1e3:.0f} ms")
    print(f"  TPOT mean  {summary['tpot_mean_ms']:.0f} ms")
    hits = sum(e.stats['cache_hits'] for e in srv.prefills)
    print(f"  APC hits   {hits}")
    kv = sum(e.stats['kv_transfer_bytes'] for e in srv.decodes)
    print(f"  P→D KV transferred {kv/1e6:.2f} MB")


if __name__ == "__main__":
    main()
