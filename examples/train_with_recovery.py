"""Train a ~100M-param model for a few hundred steps with checkpointing,
then demonstrate preemption recovery (deliverable b: training driver).

The default settings build a ≈100M-parameter qwen2-family model (12 layers,
d_model 512, vocab 32k) and run 200 steps on CPU (~10-20 min). Pass --tiny
for a fast demonstration run.

    PYTHONPATH=src python examples/train_with_recovery.py --tiny
"""
import argparse
import sys
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="omniinfer_ck_")
    if args.tiny:
        base = ["--arch", "qwen2-1.5b", "--reduced",
                "--steps", str(args.steps or 30), "--batch", "2",
                "--seq", "64", "--ckpt-dir", ckpt, "--ckpt-every", "10"]
    else:
        # ~100M params: reduced arch widened via the same launcher path
        base = ["--arch", "mamba2-130m", "--steps", str(args.steps or 200),
                "--batch", "4", "--seq", "256", "--ckpt-dir", ckpt,
                "--ckpt-every", "50"]

    print(f"== phase 1: train with simulated preemption (ckpt: {ckpt})")
    try:
        train_main(base + ["--preempt-at", str((args.steps or 30) // 2)
                           if args.tiny else "100"])
    except SystemExit as e:
        print(f"   (preempted, exit {e.code})")

    print("== phase 2: relaunch — resumes from the latest checkpoint")
    loss = train_main(base)
    print(f"final loss {loss:.4f}")


if __name__ == "__main__":
    main()
