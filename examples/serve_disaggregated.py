"""End-to-end driver (deliverable b): PD-disaggregated serving of a small MoE
model with streaming `generate()`, a failure drill (one prefill instance dies
mid-stream; OmniProxy requeues its work), and a mid-flight `abort()`.

    PYTHONPATH=src python examples/serve_disaggregated.py
"""
import numpy as np

from repro.configs import reduced_config
from repro.core.placement import calculate_imbalance
from repro.core.proxy import OASConfig
from repro.serving import SamplingParams, Server, ServerConfig


def main():
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(n_layers=2)
    print(f"arch={cfg.arch_id}: {cfg.moe.n_experts} experts top-{cfg.moe.top_k}"
          f" + {cfg.moe.n_shared_experts} shared")

    # small per-tick prefill budget: first tokens stream out while later
    # prompts are still queued, so the mid-stream failure has work to requeue
    srv = Server(cfg, ServerConfig(n_prefill=2, n_decode=1, decode_slots=4,
                                   max_len=64, chunk_tokens=8,
                                   prefill_tick_budget=8,
                                   oas=OASConfig(defer_window=0.0)))
    se = np.asarray(srv.tables["slot_expert"])
    print(f"expert slots per EP rank: {se.shape[1]} (layout {se.tolist()})")

    rng = np.random.default_rng(1)
    prompts = [tuple(rng.integers(0, 500, int(rng.integers(6, 20))).tolist())
               for _ in range(8)]
    params = [SamplingParams(temperature=0.7, top_k=32, seed=i, max_tokens=4)
              for i in range(len(prompts))]

    # stream through generate(); after the first outputs arrive (some
    # requests still queued / mid-prefill) fail a prefill instance, then
    # abort one still-running request mid-flight
    dead, drilled, abort_rid = 0, False, None
    streamed: dict[int, int] = {}
    for out in srv.generate(prompts, params, max_wall_s=180):
        streamed[out.rid] = streamed.get(out.rid, 0) + len(out.new_tokens)
        if not drilled and out.new_tokens:
            requeued = srv.proxy.mark_unhealthy("prefill", dead, 0.0)
            srv.proxy.mark_healthy("prefill", dead)
            print(f"\n!! failed prefill instance {dead} mid-stream: "
                  f"{len(requeued)} requests requeued by OmniProxy")
            drilled = True
        if drilled and abort_rid is None:
            live = [r for r in srv.proxy.inflight if streamed.get(r, 0) == 0]
            if live:
                abort_rid = live[-1]
                srv.abort(abort_rid)
                print(f"!! aborted rid {abort_rid} mid-flight")
        if out.finished:
            print(f"  rid {out.rid}: {out.n_generated} tokens "
                  f"({out.finish_reason})")

    s = srv.metrics.summary(1.0)
    print(f"\ncompleted {s['n_done']}/{len(prompts)} despite the failure "
          f"({s['n_aborted']} aborted); ttft={s['ttft_mean']:.2f}s")
    print(f"robustness: n_retries={s['n_retries']} n_errors={s['n_errors']} "
          f"n_timeouts={s['n_timeouts']} n_shed={s['n_shed']} "
          f"blocks_quarantined={s['blocks_quarantined']}")

    # quiescent-point hygiene: the failure drill + abort must leak nothing —
    # pool invariants hold and only prefix-store snapshots remain mapped
    # (this drill is also a tier-1 test: tests/test_faults.py)
    if srv.kv_arena is not None:
        srv.kv_arena.pool.check_invariants(arena=srv.kv_arena)
        assert all(isinstance(k, tuple) and k[0] == "store"
                   for k in srv.kv_arena.pool.per_request), "leaked blocks"
        print("KV pool invariants OK: zero leaked blocks, "
              "zero stale summaries")

    # expert-load imbalance picture from this run's routing
    counts = np.ones(cfg.moe.n_experts)  # uniform placeholder at tiny scale
    placement = np.zeros((srv.mesh.ep, cfg.moe.n_experts), np.int8)
    for r in range(se.shape[0]):
        for s_ in range(se.shape[1]):
            if se[r, s_] >= 0:
                placement[r, se[r, s_]] = 1
    print(f"placement imbalance B = "
          f"{calculate_imbalance(placement, counts):.3f}")


if __name__ == "__main__":
    main()
