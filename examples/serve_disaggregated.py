"""End-to-end driver (deliverable b): PD-disaggregated serving of a small MoE
model with batched requests, live OmniPlacement monitoring, and a failure
drill (one prefill instance dies mid-run; OmniProxy requeues its work).

    PYTHONPATH=src python examples/serve_disaggregated.py
"""
import time

import numpy as np

from repro.configs import reduced_config
from repro.core.placement import calculate_imbalance
from repro.core.proxy import OASConfig
from repro.serving import Server, ServerConfig


def main():
    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(n_layers=2)
    print(f"arch={cfg.arch_id}: {cfg.moe.n_experts} experts top-{cfg.moe.top_k}"
          f" + {cfg.moe.n_shared_experts} shared")

    srv = Server(cfg, ServerConfig(n_prefill=2, n_decode=1, decode_slots=4,
                                   max_len=64,
                                   oas=OASConfig(defer_window=0.0)))
    se = np.asarray(srv.tables["slot_expert"])
    print(f"expert slots per EP rank: {se.shape[1]} (layout {se.tolist()})")

    rng = np.random.default_rng(1)
    requests = [(tuple(rng.integers(0, 500, int(rng.integers(6, 20))).tolist()), 4)
                for _ in range(8)]

    # inject a prefill-instance failure after the first dispatches
    t0 = time.monotonic()
    for i, (p, m) in enumerate(requests):
        srv.submit(i, p, m, t0)
    srv._drain_actions(time.monotonic())
    dead = 0
    requeued = srv.proxy.mark_unhealthy("prefill", dead, time.monotonic())
    print(f"\n!! failed prefill instance {dead}: {len(requeued)} requests "
          f"requeued by OmniProxy")
    while srv.proxy.inflight and time.monotonic() - t0 < 180:
        srv._drain_actions(time.monotonic())
        srv._prefill_round()           # chunked prefill is budgeted per tick
        srv._decode_round()
    s = srv.metrics.summary(time.monotonic() - t0)
    print(f"completed {s['n_done']}/{len(requests)} despite the failure; "
          f"qpm={s['qpm']:.1f} ttft={s['ttft_mean']:.2f}s")

    # expert-load imbalance picture from this run's routing
    counts = np.ones(cfg.moe.n_experts)  # uniform placeholder at tiny scale
    placement = np.zeros((srv.mesh.ep, cfg.moe.n_experts), np.int8)
    for r in range(se.shape[0]):
        for s_ in range(se.shape[1]):
            if se[r, s_] >= 0:
                placement[r, se[r, s_]] = 1
    print(f"placement imbalance B = "
          f"{calculate_imbalance(placement, counts):.3f}")


if __name__ == "__main__":
    main()
