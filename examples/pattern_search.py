"""OmniAttn GA pattern search on a live model (paper §4.2, eq. 7):
train a small LM with a long-range retrieval dependency, then let the GA
find the most-compressed layer pattern that keeps ≥97% of full-KV accuracy.

    PYTHONPATH=src python examples/pattern_search.py
"""
from benchmarks.bench_accuracy import run


def main():
    r = run(steps=300)
    print("\n== OmniAttn pattern search results ==")
    print(f"full-KV retrieval accuracy        {r['acc_full_kv']:.3f}")
    print(f"default pattern (3/4 compressed)  {r['acc_default_pattern']:.3f}")
    print(f"ALL layers compressed             {r['acc_all_compressed']:.3f}")
    print(f"GA-searched pattern               {r['acc_ga_pattern']:.3f} "
          f"(kv saved: {r['ga_kv_gain']:.0%}, feasible: {r['ga_feasible']})")
    print(f"eq.5 fidelity: rel_err={r['fidelity_rel_err']}, "
          f"attn_mass={r['fidelity_attn_mass']}")


if __name__ == "__main__":
    main()
