"""Serving-core benchmark: TTFT / TPOT / QPS on a closed-loop workload over a
qwen2_1_5b-class reduced config (CPU-real), ablating the continuous-batching
and paged-KV levers:

  dense           chunked_prefill off, slot-dense decode KV: the pre-chunking,
                  pre-paging engine path (blocking whole-prompt prefill, FIFO)
  chunked         chunk-granular SRPT prefill interleaved with decode rounds,
                  radix prefix reuse off (isolates the interleave cost/benefit)
  chunked+reuse+dense
                  chunked prefill + radix prefix resume, but slot-dense decode
                  KV (isolates what physical paging adds on top)
  chunked+reuse   ServerConfig defaults: chunked prefill + radix resume +
                  physically paged decode KV with prefix-block sharing
  sampled         chunked+reuse with per-request SamplingParams (temperature /
                  top-k / top-p / seed) — records the overhead of the fused
                  device-side sampling step vs the greedy `where` branch

The workload is the paper's APC regime under closed-loop pressure: all
requests land at t=0 and most prompts share a long system prefix. The dense
path recomputes the prefix every time and starves decode meanwhile; the
chunked path resumes prefill at the radix boundary (~2.7× less prefill
compute here), which is what turns into lower mean TTFT AND lower TPOT at
higher QPS. The chunked-without-reuse row shows the interleave trade on its
own: decode rounds between chunks cost prefill latency (TTFT up) and buy
decode liveness (TPOT down) — the prefill_tick_budget knob arbitrates.

Wall-clock columns are noisy on a shared host: judge by the WORK-BASED
columns (see benchmarks/README.md). `blocks_touched` counts full-attention
KV blocks with resident tokens attended per decode across the run — the
dense layout always pays max_len worth of cache per slot, the paged kernel
compute-skips non-resident blocks.
`blocks_shared` counts prefix blocks MAPPED at admission (refcounted, zero
copy) vs `blocks_fresh` allocated-and-written; a prefix-sharing admission
copies only the partial tail block and the suffix.
`prefill_kv_peak_blocks` is the peak KV blocks pinned by prefill-side state:
paged prefill allocates per chunk (∝ prompt length) and is asserted strictly
below the dense engines, which pin blocks_for(max_len) per live task.
`handoff_copy_bytes` is the full-attention KV physically copied at
admission: asserted ZERO on the paged path (block-table transfer) and equal
to the max_len dense scatter on the compat paths.

Greedy decode outputs are asserted identical across all greedy variants (the
chunked and paged paths are numerically exact; argmax at float32 must
agree). Every variant additionally asserts `host_fetches == steps` on the
decode engine: sampling runs inside the batched jit step, so per-request
decoding config adds ZERO per-token host syncs.
"""
from __future__ import annotations

import numpy as np


def _workload(vocab: int, n: int, sampled: bool = False):
    """Closed-loop shared-prefix pressure, all submitted at t=0: two of
    three prompts carry a 384-token system prefix (+64 distinct tokens,
    ~55 ms prefill at this config); the rest are short. Every request
    queues behind the aggregate prefill backlog, so the prefill compute the
    radix cache eliminates converts directly into mean-TTFT reduction."""
    from repro.serving import SamplingParams
    rng = np.random.default_rng(7)
    base = tuple(rng.integers(0, vocab, 384))
    reqs = []
    for i in range(n):
        spec = SamplingParams(temperature=0.9, top_k=64, top_p=0.95,
                              seed=900 + i, max_tokens=4) if sampled else 4
        if i % 3 != 2:
            reqs.append((base + tuple(rng.integers(0, vocab, 64)), spec))
        else:
            reqs.append((tuple(rng.integers(0, vocab, 16)), spec))
    return reqs


def _build(chunked: bool, reuse: bool, paged: bool):
    from repro.configs import reduced_config
    from repro.core.proxy import MetricsAggregator, OASConfig
    from repro.serving import Server, ServerConfig

    # large enough that prefill compute (~45 ms / 320 tokens) dominates the
    # per-tick dispatch overhead — the regime where chunk-granular scheduling
    # has something real to win
    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        d_model=384, d_ff=768, n_heads=4, n_kv_heads=2, head_dim=64,
        vocab_size=2048, attn_q_chunk=128, attn_kv_chunk=128)
    scfg = ServerConfig(
        n_prefill=1, n_decode=1, decode_slots=6, max_len=512,
        chunked_prefill=chunked, chunk_tokens=128, prefill_tick_budget=512,
        prefix_reuse=reuse, paged_kv=paged, kv_blocks=320,
        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0] * cfg.n_layers)
    _warm(srv, cfg)
    srv.metrics = MetricsAggregator()
    for e in srv.prefills:
        # warm prompts parked in the prefix store would pin arena blocks
        # into the measured run — drop them (they are prefix-free vs the
        # workload anyway)
        e.store.clear()
        e.stats.update(prefills=0, cache_hits=0, prefix_hits=0,
                       reused_tokens=0, tokens=0, chunks=0, busy_s=0.0,
                       host_fetches=0, blocks_mapped=0,
                       prefill_kv_peak_blocks=0, defers=0)
    for e in srv.decodes:
        e.stats.update(steps=0, tokens=0, busy_s=0.0, kv_transfer_bytes=0,
                       kv_transfer_bytes_padded=0, handoff_copy_bytes=0,
                       admits=0, preemptions=0, blocks_touched=0,
                       blocks_shared=0, blocks_fresh=0, host_fetches=0)
    return cfg, srv


def _warm(srv, cfg):
    """Compile every jit entry outside the timed run: all pow2 chunk/prefill
    buckets (budget slicing and snapshot boundaries can produce any of them)
    and all pow2 admission-batch sizes. Warm prompts are mutually prefix-free
    and practically disjoint from the random workload, so the prefix store
    carries no usable entries into the measurement (and _build drops them
    afterwards so they don't pin arena blocks).

    On the paged path every admission consumes its own BlockHandoff (pool
    ownership transfers exactly once), so each warm admission prefills a
    fresh prompt instead of re-admitting one record under many rids."""
    import jax.numpy as jnp

    from repro.serving import BlockHandoff, SamplingParams

    pe, de = srv.prefills[0], srv.decodes[0]
    lens = (5, 12, 24, 64, 320)
    # first-token sampler buckets: several prompts can finish in one engine
    # round during the measurement (greedy and sampled rows share a trace —
    # the params are data, not shape)
    dummy = jnp.zeros((1, cfg.vocab_size), jnp.float32)
    sp = SamplingParams(temperature=0.9, top_k=64, top_p=0.95, seed=0)
    for k in (1, 2, 4, 8):
        pe.sample_first([dummy] * k, [sp] * k, list(range(k)), [8] * k)
    rid = 9000
    for k in (1, 2, 4, 8):
        batch = []
        for j in range(k):
            n = lens[(rid - 9000) % len(lens)]
            p = tuple((1000 + 131 * rid + 7 * j2) % cfg.vocab_size
                      for j2 in range(n))
            cache, first, _ = pe.process(p)
            batch.append((rid, cache, first, n, 0))
            rid += 1
        granted = de.admit_batch(batch)
        de.step()
        for r, ok in granted.items():
            if ok:
                de.release(r)
        for r, c, *_ in batch:
            # a denied admission (k=8 exceeds decode_slots) hands its
            # BlockHandoff back — release it or its arena blocks stay
            # pinned through the measured run
            if not granted.get(r, False) and isinstance(c, BlockHandoff):
                de.pool.release(c.key)


def run(n_requests: int = 12):
    """→ list of per-variant result dicts (also checks greedy equality and
    the zero-new-host-sync property of device-side sampling)."""
    # one lever per step: dense→chunked isolates the interleave trade,
    # chunked+reuse+dense→chunked+reuse isolates physical paging, and
    # sampled puts per-request temperature/top-k/top-p/seed on top of the
    # server defaults to price the fused sampling step
    variants = [("dense", False, False, False, False),
                ("chunked", True, False, False, False),
                ("chunked+reuse+dense", True, True, False, False),
                ("chunked+reuse", True, True, True, False),
                ("sampled", True, True, True, True)]
    results, outputs = [], {}
    for name, chunked, reuse, paged, sampled in variants:
        cfg, srv = _build(chunked, reuse, paged)
        reqs = _workload(cfg.vocab_size, n_requests, sampled=sampled)
        s = srv.run(reqs, max_wall_s=300)
        outputs[name] = {r.rid: tuple(r.output_tokens)
                         for r in srv.metrics.done}
        ps = s["prefill_stats"][0]
        ds = s["decode_stats"][0]
        # host-fetch tripwires: host_fetches is incremented at every
        # device→host fetch site in the engines, so a code path that adds a
        # per-token or per-record sync must either bump the counter (and
        # trip these) or show up in review as an uncounted np.asarray
        assert ds["host_fetches"] == ds["steps"], \
            f"{name}: decode host fetches {ds['host_fetches']} != steps"
        n_finished = ps["prefills"] + ps["cache_hits"]
        assert ps["host_fetches"] <= n_finished, \
            f"{name}: prefill first-token fetches not batched"
        if reuse:
            # shared-prefix sharers complete in bursts after the snapshot
            # boundary: first-token sampling MUST be batching multiple
            # finishes per fused call (a per-record sync would equal
            # n_finished and fail strictly)
            assert ps["host_fetches"] < n_finished, \
                f"{name}: first-token sampling not actually batched " \
                f"({ps['host_fetches']} fetches / {n_finished} prompts)"
        # zero-copy gate: the paged path must never copy full-attention KV
        # at admission, and prefill must pin blocks ∝ prompt length — the
        # dense engines pin blocks_for(max_len) per live task
        if paged:
            assert ds["handoff_copy_bytes"] == 0, \
                f"{name}: paged handoff copied {ds['handoff_copy_bytes']}B"
        else:
            assert ds["handoff_copy_bytes"] > 0
        assert s["kv_transfer_true_bytes"] < s["kv_transfer_padded_bytes"], \
            f"{name}: transfer meter still charges max_len padding"
        # bytes-true KV residency: capacity bytes the decode KV plane pins
        # (dtype-true per-block accounting on the paged path — int8 arenas
        # halve it) and the max_len-stream concurrency that buys
        if srv.kv_arena is not None:
            pool = srv.kv_arena.pool
            resident_bytes = pool.n_blocks * srv.kv_arena.block_nbytes
            admissible = pool.n_blocks // pool.blocks_for(srv.scfg.max_len)
        else:
            eng = srv.decodes[0]
            resident_bytes = srv.scfg.decode_slots * eng._dense_kv_nbytes
            admissible = srv.scfg.decode_slots
        results.append({
            "variant": name,
            "n_done": s["n_done"],
            "qps": s["qpm"] / 60.0,
            "resident_bytes": resident_bytes,
            "admissible_slots": admissible,
            "ttft_mean_s": s["ttft_mean"],
            "ttft_p99_s": s["ttft_p99"],
            "tpot_mean_ms": s["tpot_mean_ms"],
            "ott_tok_s": s["ott_tok_s"],
            "prefill_tokens": ps["tokens"],
            "reused_tokens": ps["reused_tokens"],
            "prefix_hits": ps["prefix_hits"],
            "tok_per_step": ds["tokens"] / max(ds["steps"], 1),
            "blocks_touched": ds["blocks_touched"],
            "blocks_shared": ds["blocks_shared"] + ps["blocks_mapped"],
            "blocks_fresh": ds["blocks_fresh"],
            "host_fetches": ds["host_fetches"],
            "first_fetches": ps["host_fetches"],
            "prefill_kv_peak_blocks": ps["prefill_kv_peak_blocks"],
            "handoff_copy_bytes": ds["handoff_copy_bytes"],
        })
    ref = outputs["dense"]
    for name, *_ in variants[1:]:
        if name == "sampled":
            continue                    # stochastic by design
        assert outputs[name] == ref, \
            f"greedy outputs diverged between dense and {name} paths"
    assert outputs["sampled"] != ref, "sampled variant decoded greedily"
    # prefill-phase memory gate: paged prefill's peak block footprint must
    # sit strictly below the dense engines' per-task max_len pinning
    dense_peak = min(r["prefill_kv_peak_blocks"] for r in results
                     if r["variant"] in ("dense", "chunked",
                                         "chunked+reuse+dense"))
    paged_peak = max(r["prefill_kv_peak_blocks"] for r in results
                     if r["variant"] in ("chunked+reuse", "sampled"))
    assert paged_peak < dense_peak, \
        f"paged prefill peak {paged_peak} blocks !< dense {dense_peak}"
    return results


# ----------------------------------------------------------------------
# OmniAttn online-sparsity ablation: long-context decode with per-block
# key summaries + query-aware top-k block selection (see docs/serving.md
# §Online sparsity). Run with `--sparse`.
def _sharpen_attention(params, factor: float = 60.0):
    """Scale every layer's wq so attention scores are sharply peaked.

    Random-init attention is near-uniform (score std ~0.04 at this scale)
    — a regime where NO sparsity method can keep high attention mass and
    which trained LLMs do not exhibit (the paper's premise is concentrated
    attention). Scaling the query projection widens the score distribution
    (std ∝ factor; ~2.4 at 60), giving the measured `attn_mass_kept` a
    realistic concentrated target while keeping every greedy-equality
    assert bit-exact (all variants share the sharpened params)."""
    def one(p):
        if "wq" in p:
            p = dict(p)
            p["wq"] = p["wq"] * factor
        return p
    stack = params["stack"]
    return dict(params, stack={
        "period": tuple(one(p) for p in stack["period"]),
        "rem": tuple(one(p) for p in stack["rem"])})


def _sparse_workload(vocab: int, n: int, block_size: int = 8):
    """Long-context closed-loop pressure: every prompt is 512+ tokens (64+
    KV blocks at block_size=8) sharing a 384-token system prefix, decoding
    16 tokens each — decode runs entirely in the long-context regime where
    block selection has something to skip.

    Prompts are built from block-aligned RUNS of repeated tokens: keys
    inside one KV block are then tightly clustered (identical pre-RoPE),
    which is what makes the per-block [kmin, kmax] bounds discriminative.
    This stands in for the semantic locality of natural text — with fully
    i.i.d. random tokens the channel extremes of every block look alike
    and block-granular bounds (Quest's, ours) cannot rank blocks."""
    rng = np.random.default_rng(11)

    def runs(n_tokens):
        toks = []
        while len(toks) < n_tokens:
            toks += [int(rng.integers(0, vocab))] * block_size
        return tuple(toks[:n_tokens])

    base = runs(384)                    # multiple of block_size: suffixes
    return [(base + runs(128 + 8 * i), 16) for i in range(n)]


def _build_sparse(params, topk_blocks: int, topk_frac: float, measure: bool):
    from repro.configs import reduced_config
    from repro.core.proxy import MetricsAggregator, OASConfig
    from repro.serving import Server, ServerConfig

    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        d_model=256, d_ff=512, n_heads=2, n_kv_heads=2, head_dim=64,
        vocab_size=2048, attn_q_chunk=128, attn_kv_chunk=128,
        omniattn_topk_blocks=topk_blocks, omniattn_topk_frac=topk_frac,
        omniattn_topk_sink_blocks=1, omniattn_topk_recent_blocks=2,
        omniattn_topk_measure_mass=measure)
    scfg = ServerConfig(
        n_prefill=1, n_decode=1, decode_slots=4, max_len=768,
        chunk_tokens=128, prefill_tick_budget=768, prefix_reuse=True,
        paged_kv=True, kv_blocks=768, kv_block_size=8,
        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0] * cfg.n_layers, params=params)
    # light warm: one long + one short prompt compiles the chunk buckets,
    # size-1 admission/sampler, and the long-context decode bucket outside
    # the measured window (work-based columns are the judged figures)
    rng = np.random.default_rng(99)
    srv.run([(tuple(rng.integers(0, cfg.vocab_size, 520)), 3),
             (tuple(rng.integers(0, cfg.vocab_size, 24)), 2)])
    srv.metrics = MetricsAggregator()
    for e in srv.prefills:
        e.store.clear()
        e.stats.update(prefills=0, cache_hits=0, prefix_hits=0,
                       reused_tokens=0, tokens=0, chunks=0, busy_s=0.0,
                       host_fetches=0, blocks_mapped=0,
                       prefill_kv_peak_blocks=0, defers=0)
    for e in srv.decodes:
        e.stats.update(steps=0, tokens=0, busy_s=0.0, kv_transfer_bytes=0,
                       kv_transfer_bytes_padded=0, handoff_copy_bytes=0,
                       admits=0, preemptions=0, blocks_touched=0,
                       blocks_shared=0, blocks_fresh=0, host_fetches=0)
        if e.sparsity is not None:
            from repro.serving import SparsityController
            e.stats.update(SparsityController.stats_keys())
    return cfg, srv


def run_sparse(n_requests: int = 6):
    """→ per-variant result rows for the online-sparsity ablation.

      exact        paged decode, online sparsity off (the PR-4 engine)
      sparse-full  top-k selection ACTIVE with a budget covering every
                   resident block — must be greedy bit-identical to exact
      sparse-50    50% per-slot block budget (sink + 2 recent blocks
                   always kept), exact attention-mass measurement on

    Asserts: full-budget greedy equality; `blocks_attended ≤ 0.6 ×
    blocks_touched` on sparse-50 at long context while `attn_mass_kept ≥
    0.95`; `host_fetches == steps` everywhere (scoring, selection and the
    stats window all live inside the batched step jit)."""
    import jax

    from repro.configs import reduced_config
    from repro.distributed.ctx import local_mesh_ctx
    from repro.models import LM

    # two head-groups: the block table is per-slot, so every head votes
    # into ONE selection — fewer voters keep the vote sharp (a per-head
    # table is a Quest refinement our paged plane does not carry)
    cfg0 = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        d_model=256, d_ff=512, n_heads=2, n_kv_heads=2, head_dim=64,
        vocab_size=2048, attn_q_chunk=128, attn_kv_chunk=128)
    lm = LM.build(cfg0, local_mesh_ctx(), pattern=[0] * cfg0.n_layers)
    params = _sharpen_attention(lm.init(jax.random.PRNGKey(0)))
    # full budget: ≥ blocks_for(longest prompt + decode) but < the bucketed
    # table width (96), so the selection path itself runs and must keep all
    variants = [("exact", 0, 0.0, False),
                ("sparse-full", 80, 0.0, True),
                ("sparse-50", 0, 0.5, True)]
    results, outputs = [], {}
    for name, blocks, frac, measure in variants:
        cfg, srv = _build_sparse(params, blocks, frac, measure)
        reqs = _sparse_workload(cfg.vocab_size, n_requests)
        s = srv.run(reqs, max_wall_s=600)
        outputs[name] = {r.rid: tuple(r.output_tokens)
                         for r in srv.metrics.done}
        ds = s["decode_stats"][0]
        assert ds["host_fetches"] == ds["steps"], \
            f"{name}: scoring/selection added host syncs " \
            f"({ds['host_fetches']} fetches / {ds['steps']} steps)"
        results.append({
            "variant": name, "n_done": s["n_done"],
            "tpot_mean_ms": s["tpot_mean_ms"],
            "tok_per_step": ds["tokens"] / max(ds["steps"], 1),
            "blocks_touched": ds["blocks_touched"],
            "blocks_scored": ds.get("blocks_scored", 0),
            "blocks_attended": ds.get("blocks_attended",
                                      ds["blocks_touched"]),
            "attn_mass_kept": s["attn_mass_kept"],
            "host_fetches": ds["host_fetches"],
        })
    assert outputs["sparse-full"] == outputs["exact"], \
        "full-budget sparse decode diverged from exact paged decode"
    full = next(r for r in results if r["variant"] == "sparse-full")
    half = next(r for r in results if r["variant"] == "sparse-50")
    # the full-budget run keeps every resident block: measured mass is 1
    assert full["attn_mass_kept"] >= 0.999, full["attn_mass_kept"]
    assert 0 < half["blocks_attended"] <= 0.6 * half["blocks_touched"], \
        f"sparse-50 attended {half['blocks_attended']} blocks vs " \
        f"{half['blocks_touched']} touched — selection not biting"
    assert half["attn_mass_kept"] >= 0.95, \
        f"sparse-50 kept only {half['attn_mass_kept']:.3f} attention mass"
    # scored ≈ touched (same resident-block figure from two independent
    # meters: the in-jit aux and the host-side accounting)
    assert abs(half["blocks_scored"] - half["blocks_touched"]) <= \
        half["blocks_touched"] * 0.02 + 2
    return results


def main_sparse(fast: bool = False):
    print("variant,n_done,tpot_mean_ms,tok_per_step,blocks_touched,"
          "blocks_scored,blocks_attended,attn_mass_kept,host_fetches")
    rows = run_sparse(4 if fast else 6)
    for r in rows:
        print(f"{r['variant']},{r['n_done']},{r['tpot_mean_ms']:.2f},"
              f"{r['tok_per_step']:.2f},{r['blocks_touched']},"
              f"{r['blocks_scored']},{r['blocks_attended']},"
              f"{r['attn_mass_kept']:.4f},{r['host_fetches']}", flush=True)
    half = next(r for r in rows if r["variant"] == "sparse-50")
    exact = next(r for r in rows if r["variant"] == "exact")
    print(f"# full-budget selection greedy bit-identical to exact paged "
          f"decode; 50% budget attends {half['blocks_attended']} blocks vs "
          f"{exact['blocks_touched']} touched exact "
          f"({half['blocks_attended'] / max(half['blocks_touched'], 1):.2f}"
          f"× its own touched) while keeping "
          f"{half['attn_mass_kept']:.3f} of exact attention mass, with "
          f"host_fetches == steps — scoring, selection and stats all run "
          f"inside the batched step jit", flush=True)


# ----------------------------------------------------------------------
# SpecPlane ablation: model-free speculative decoding (radix/n-gram prompt-
# lookup drafting + batched multi-token verify with block/summary rollback;
# see docs/serving.md §Speculative decoding). Run with `--spec`.
def _spec_workload(vocab: int, n: int):
    """Repetitive closed-loop decode pressure — the regime prompt-lookup
    speculation targets (extraction, code, JSON, self-quoting chat stand-ins):
    every prompt is a short gram repeated to ~40 tokens, decoding 32 tokens.
    Greedy continuations of a cyclic prompt re-enter the cycle, so the
    request's own history proposes drafts the verify keeps accepting."""
    rng = np.random.default_rng(17)
    reqs = []
    for i in range(n):
        gram = tuple(int(t) for t in rng.integers(0, vocab, 5 + (i % 3)))
        reps = -(-40 // len(gram))
        reqs.append(((gram * reps)[:40], 32))
    return reqs


def _build_spec(params, spec):
    from repro.configs import reduced_config
    from repro.core.proxy import MetricsAggregator, OASConfig
    from repro.serving import Server, ServerConfig, SpecController

    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        d_model=256, d_ff=512, n_heads=2, n_kv_heads=2, head_dim=64,
        vocab_size=256, attn_q_chunk=128, attn_kv_chunk=128)
    scfg = ServerConfig(
        n_prefill=1, n_decode=1, decode_slots=4, max_len=256,
        chunk_tokens=64, prefill_tick_budget=256, prefix_reuse=True,
        paged_kv=True, kv_blocks=128, kv_block_size=16, spec=spec,
        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0] * cfg.n_layers, params=params)
    # warm every jit entry the measured run will hit — prefill chunk
    # buckets, admission, the baseline step AND (on the spec row) the
    # verify window at the same table bucket — with a repetitive prompt so
    # the spec server actually traces the verify path
    rng = np.random.default_rng(99)
    warm_gram = tuple(int(t) for t in rng.integers(0, cfg.vocab_size, 7))
    srv.run([((warm_gram * 8)[:44], 24),
             (tuple(rng.integers(0, cfg.vocab_size, 12)), 4)])
    srv.metrics = MetricsAggregator()
    for e in srv.prefills:
        e.store.clear()
        e.stats.update(prefills=0, cache_hits=0, prefix_hits=0,
                       reused_tokens=0, tokens=0, chunks=0, busy_s=0.0,
                       host_fetches=0, blocks_mapped=0,
                       prefill_kv_peak_blocks=0, defers=0)
    for e in srv.decodes:
        e.take_spec_stats()                 # drop the warmup window
        e.stats.update(steps=0, tokens=0, busy_s=0.0, kv_transfer_bytes=0,
                       kv_transfer_bytes_padded=0, handoff_copy_bytes=0,
                       admits=0, preemptions=0, blocks_touched=0,
                       blocks_shared=0, blocks_fresh=0, host_fetches=0)
        if e.spec_ctl is not None:
            e.stats.update(SpecController.stats_keys())
    return cfg, srv


def run_spec(n_requests: int = 6):
    """→ per-variant result rows for the speculative-decoding ablation.

      exact   the unchanged paged decode engine (one token per step)
      spec    SpecConfig(k=4): prompt-lookup drafting + batched verify

    Asserts: greedy outputs BIT-IDENTICAL between the rows (the verify
    accepts exactly the prefix matching its own argmax and re-derives every
    emitted token, so drafts can change only throughput, never content);
    `tok_per_step` ≥ 1.5× exact on this repetitive workload;
    `host_fetches == steps` on both rows (the verify window is one fetch);
    pool/summary invariants hold at quiescence (every rejected draft rolled
    back without leaving a stale block summary)."""
    import jax

    from repro.configs import reduced_config
    from repro.distributed.ctx import local_mesh_ctx
    from repro.models import LM
    from repro.serving import SpecConfig

    cfg0 = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        d_model=256, d_ff=512, n_heads=2, n_kv_heads=2, head_dim=64,
        vocab_size=256, attn_q_chunk=128, attn_kv_chunk=128)
    lm = LM.build(cfg0, local_mesh_ctx(), pattern=[0] * cfg0.n_layers)
    params = lm.init(jax.random.PRNGKey(0))
    variants = [("exact", None), ("spec", SpecConfig(k=4))]
    results, outputs = [], {}
    for name, sp in variants:
        cfg, srv = _build_spec(params, sp)
        reqs = _spec_workload(cfg.vocab_size, n_requests)
        s = srv.run(reqs, max_wall_s=600)
        outputs[name] = {r.rid: tuple(r.output_tokens)
                         for r in srv.metrics.done}
        ds = s["decode_stats"][0]
        assert s["n_done"] == n_requests, f"{name}: incomplete run"
        assert ds["host_fetches"] == ds["steps"], \
            f"{name}: speculation added host syncs " \
            f"({ds['host_fetches']} fetches / {ds['steps']} steps)"
        pool = srv.kv_arena.pool
        pool.check_invariants(arena=srv.kv_arena)
        results.append({
            "variant": name, "n_done": s["n_done"],
            "tpot_mean_ms": s["tpot_mean_ms"],
            "tok_per_step": ds["tokens"] / max(ds["steps"], 1),
            "draft_acceptance": s["draft_acceptance"],
            "tokens_per_verify": s["tokens_per_verify"],
            "spec_verifies": s["spec_verifies"],
            "host_fetches": ds["host_fetches"],
        })
    assert outputs["spec"] == outputs["exact"], \
        "speculative greedy outputs diverged from exact paged decode"
    exact = next(r for r in results if r["variant"] == "exact")
    spec = next(r for r in results if r["variant"] == "spec")
    ratio = spec["tok_per_step"] / max(exact["tok_per_step"], 1e-9)
    assert ratio >= 1.5, \
        f"spec tok_per_step only {ratio:.2f}× exact on a repetitive " \
        f"workload (acceptance {spec['draft_acceptance']:.2f})"
    assert spec["spec_verifies"] > 0 and spec["draft_acceptance"] > 0.5
    spec["speedup_x"] = ratio
    return results


def main_spec(fast: bool = False):
    print("variant,n_done,tpot_mean_ms,tok_per_step,draft_acceptance,"
          "tokens_per_verify,spec_verifies,host_fetches")
    rows = run_spec(4 if fast else 6)
    for r in rows:
        da = r["draft_acceptance"]
        tv = r["tokens_per_verify"]
        print(f"{r['variant']},{r['n_done']},{r['tpot_mean_ms']:.2f},"
              f"{r['tok_per_step']:.2f},{da:.3f},{tv:.2f},"
              f"{r['spec_verifies']},{r['host_fetches']}", flush=True)
    spec = next(r for r in rows if r["variant"] == "spec")
    print(f"# greedy outputs bit-identical to exact paged decode; "
          f"model-free drafting (prompt-lookup n-grams) accepted "
          f"{spec['draft_acceptance']:.2f} of drafted tokens, "
          f"{spec['tokens_per_verify']:.2f} tokens per verify step — "
          f"{spec['speedup_x']:.2f}× tok/step over exact on the repetitive "
          f"workload, with host_fetches == steps (the whole verify window "
          f"is one fetch) and zero stale block summaries after every "
          f"rollback", flush=True)


# ----------------------------------------------------------------------
# QuantPlane ablation: int8 paged KV arenas with per-block scales (see
# docs/serving.md §Quantized arenas). Run with `--quant`. The residency
# claim is bytes-true and assert-gated: the same ServerConfig with quant on
# pins ≈ half the HBM bytes per KV block (int8 payload + f32 scale plane vs
# f32 payload), which at a MATCHED HBM budget admits ≥ 1.9× the max_len
# decode streams — while greedy outputs stay bit-identical to the f32 run
# on this config (in-tile dequant, zero-stale-scales).
def _quant_workload(vocab: int, n: int):
    """Shared-prefix closed-loop pressure: CoW block sharing, store
    adoption/resume and tail copies all run under quant during the
    measured window, so the bit-identity assert covers every scale-plane
    lifecycle path, not just the decode append."""
    rng = np.random.default_rng(23)
    base = tuple(rng.integers(0, vocab, 48))
    return [(base + tuple(rng.integers(0, vocab, 12 + 4 * i)), 8)
            for i in range(n)]


def _build_quant(params, quant):
    from repro.configs import reduced_config
    from repro.core.proxy import MetricsAggregator, OASConfig
    from repro.serving import Server, ServerConfig
    from repro.serving.quant import QuantConfig

    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        d_model=256, d_ff=512, n_heads=2, n_kv_heads=2, head_dim=64,
        vocab_size=2048, attn_q_chunk=128, attn_kv_chunk=128)
    scfg = ServerConfig(
        n_prefill=1, n_decode=1, decode_slots=4, max_len=256,
        chunk_tokens=64, prefill_tick_budget=256, prefix_reuse=True,
        paged_kv=True, kv_blocks=96, kv_block_size=16,
        quant=QuantConfig() if quant else None,
        oas=OASConfig(defer_window=0.0))
    srv = Server(cfg, scfg, pattern=[0] * cfg.n_layers, params=params)
    rng = np.random.default_rng(99)
    srv.run([(tuple(rng.integers(0, cfg.vocab_size, 40)), 3),
             (tuple(rng.integers(0, cfg.vocab_size, 12)), 2)])
    srv.metrics = MetricsAggregator()
    for e in srv.prefills:
        e.store.clear()
        e.stats.update(prefills=0, cache_hits=0, prefix_hits=0,
                       reused_tokens=0, tokens=0, chunks=0, busy_s=0.0,
                       host_fetches=0, blocks_mapped=0,
                       prefill_kv_peak_blocks=0, defers=0)
    for e in srv.decodes:
        e.stats.update(steps=0, tokens=0, busy_s=0.0, kv_transfer_bytes=0,
                       kv_transfer_bytes_padded=0, handoff_copy_bytes=0,
                       admits=0, preemptions=0, blocks_touched=0,
                       blocks_shared=0, blocks_fresh=0, host_fetches=0)
    return cfg, srv


def run_quant(n_requests: int = 6):
    """→ per-variant rows for the quantized-arena ablation.

      f32    the unchanged paged serving engine (f32 arenas)
      int8   QuantConfig(): int8 payloads + per-block/per-token scales

    Asserts: greedy outputs BIT-IDENTICAL between the rows on this config;
    bytes-true per-block residency int8/f32 in (0.35, 0.55); at the f32
    row's HBM budget the int8 arenas admit ≥ 1.9× the max_len streams;
    `host_fetches == steps`; the quiescent arena passes the extended
    summary + scale scan (zero stale scales)."""
    import jax

    from repro.configs import reduced_config
    from repro.distributed.ctx import local_mesh_ctx
    from repro.models import LM

    cfg0 = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        d_model=256, d_ff=512, n_heads=2, n_kv_heads=2, head_dim=64,
        vocab_size=2048, attn_q_chunk=128, attn_kv_chunk=128)
    lm = LM.build(cfg0, local_mesh_ctx(), pattern=[0] * cfg0.n_layers)
    params = lm.init(jax.random.PRNGKey(0))
    results, outputs = [], {}
    for name, quant in (("f32", False), ("int8", True)):
        cfg, srv = _build_quant(params, quant)
        reqs = _quant_workload(cfg.vocab_size, n_requests)
        s = srv.run(reqs, max_wall_s=600)
        outputs[name] = {r.rid: tuple(r.output_tokens)
                         for r in srv.metrics.done}
        ds = s["decode_stats"][0]
        assert s["n_done"] == n_requests, f"{name}: incomplete run"
        assert ds["host_fetches"] == ds["steps"], \
            f"{name}: quant added host syncs"
        srv.kv_arena.check_summaries()
        pool = srv.kv_arena.pool
        pool.check_invariants(arena=srv.kv_arena)
        bnb = srv.kv_arena.block_nbytes
        results.append({
            "variant": name, "n_done": s["n_done"],
            "tpot_mean_ms": s["tpot_mean_ms"],
            "tok_per_step": ds["tokens"] / max(ds["steps"], 1),
            "block_bytes": bnb,
            "resident_bytes": pool.n_blocks * bnb,
            "blocks_per_stream": pool.blocks_for(srv.scfg.max_len),
            "quant_layers": ds.get("quant_layers", 0),
            "host_fetches": ds["host_fetches"],
        })
    assert outputs["int8"] == outputs["f32"], \
        "quantized greedy outputs diverged from the f32 paged run"
    f32 = next(r for r in results if r["variant"] == "f32")
    int8 = next(r for r in results if r["variant"] == "int8")
    ratio = int8["resident_bytes"] / f32["resident_bytes"]
    assert 0.35 < ratio < 0.55, \
        f"int8 residency {ratio:.3f}× f32 — outside the bytes-true " \
        f"halving envelope (payload 0.25×/0.5×? scale plane mis-sized?)"
    # matched HBM budget: the f32 arena's capacity bytes, re-spent on
    # int8 blocks → admissible max_len decode streams
    budget = f32["resident_bytes"]
    for r in results:
        r["admissible_slots"] = \
            (budget // r["block_bytes"]) // r["blocks_per_stream"]
    gain = int8["admissible_slots"] / max(f32["admissible_slots"], 1)
    assert gain >= 1.9, \
        f"int8 admits only {gain:.2f}× the f32 streams at a matched " \
        f"HBM budget (block {int8['block_bytes']}B vs {f32['block_bytes']}B)"
    int8["residency_x"] = gain
    return results


def main_quant(fast: bool = False):
    print("variant,n_done,tpot_mean_ms,tok_per_step,block_bytes,"
          "resident_bytes,admissible_slots,quant_layers,host_fetches")
    rows = run_quant(4 if fast else 6)
    for r in rows:
        print(f"{r['variant']},{r['n_done']},{r['tpot_mean_ms']:.2f},"
              f"{r['tok_per_step']:.2f},{r['block_bytes']},"
              f"{r['resident_bytes']},{r['admissible_slots']},"
              f"{r['quant_layers']},{r['host_fetches']}", flush=True)
    f32 = next(r for r in rows if r["variant"] == "f32")
    int8 = next(r for r in rows if r["variant"] == "int8")
    print(f"# greedy outputs bit-identical to the f32 paged run; int8 "
          f"arenas pin {int8['resident_bytes'] / f32['resident_bytes']:.2f}×"
          f" the f32 bytes per resident block (dtype-true accounting, "
          f"scale plane included), admitting {int8['residency_x']:.2f}× "
          f"the max_len decode streams at the f32 row's HBM budget — with "
          f"host_fetches == steps (in-tile dequant adds zero syncs) and "
          f"zero stale summaries OR scales at quiescence", flush=True)


# ----------------------------------------------------------------------
# FaultPlane chaos soak: seeded deterministic fault injection over the full
# PD-disaggregated paged stack (see docs/serving.md §Failure model &
# recovery). Run with `--chaos`. Every row is one fault seed; the harness
# ASSERTS the recovery contract rather than timing it: all requests
# complete, greedy outputs are bit-identical to the fault-free baseline,
# and the quiescent pool passes invariants with zero leaked blocks.
def _build_chaos(faults=None):
    from repro.configs import reduced_config
    from repro.core.proxy import OASConfig
    from repro.serving import Server, ServerConfig

    cfg = reduced_config("qwen2-1.5b").with_updates(
        compute_dtype="float32", param_dtype="float32", n_layers=2,
        d_model=384, d_ff=768, n_heads=4, n_kv_heads=2, head_dim=64,
        vocab_size=2048, attn_q_chunk=128, attn_kv_chunk=128)
    scfg = ServerConfig(
        n_prefill=2, n_decode=2, decode_slots=4, max_len=128,
        chunk_tokens=32, prefill_tick_budget=64, kv_blocks=96,
        watchdog_steps=200,
        oas=OASConfig(defer_window=0.0, max_retries=10))
    # pattern=[0,0]: full attention in every layer so the per-block summary
    # plane exists — kv_corrupt faults are DETECTABLE (and injected)
    return cfg, Server(cfg, scfg, pattern=[0] * cfg.n_layers, faults=faults)


def _chaos_workload(vocab: int, n: int):
    rng = np.random.default_rng(42)
    return [(tuple(rng.integers(0, vocab, 24)), 12) for _ in range(n)]


def run_chaos(seeds=(1, 2, 5, 7, 9), n_requests: int = 8):
    """→ per-seed rows. Asserts, per seed: every request completed (none
    shed at this load), outputs bit-identical to the fault-free baseline,
    at least one fault actually fired, quarantine accounting consistent,
    and pool/summary invariants with zero leaked block mappings."""
    from repro.serving import FaultConfig, FaultPlane

    cfg, base = _build_chaos()
    reqs = _chaos_workload(cfg.vocab_size, n_requests)
    base.run(reqs, max_wall_s=300)
    ref = {r.rid: tuple(r.output_tokens) for r in base.metrics.done}
    assert len(ref) == n_requests, "fault-free baseline did not complete"
    rows = []
    for seed in seeds:
        plane = FaultPlane(FaultConfig(seed=seed, horizon=20))
        _, srv = _build_chaos(faults=plane)
        s = srv.run(reqs, max_wall_s=300)
        outs = {r.rid: tuple(r.output_tokens) for r in srv.metrics.done}
        assert len(outs) == n_requests, \
            f"seed {seed}: only {len(outs)}/{n_requests} completed " \
            f"(errors={s['n_errors']} timeouts={s['n_timeouts']})"
        assert outs == ref, \
            f"seed {seed}: outputs diverged from the fault-free run"
        assert sum(plane.injected.values()) > 0, \
            f"seed {seed}: schedule fired nothing — horizon vs run length"
        pool = srv.kv_arena.pool
        assert len(pool.quarantined) == s["blocks_quarantined"]
        pool.check_invariants(arena=srv.kv_arena)
        for key in pool.per_request:
            assert isinstance(key, tuple) and key[0] == "store", \
                f"seed {seed}: leaked block mapping under {key!r}"
        rows.append({
            "seed": seed, "n_done": s["n_done"],
            "n_retries": s["n_retries"], "n_timeouts": s["n_timeouts"],
            "n_shed": s["n_shed"],
            "blocks_quarantined": s["blocks_quarantined"],
            "handoffs_swept": s["n_handoffs_swept"],
            "faults_injected": sum(plane.injected.values()),
            "faults_skipped": sum(plane.skipped.values()),
        })
    return rows


def main_chaos(fast: bool = False):
    print("seed,n_done,n_retries,n_timeouts,n_shed,blocks_quarantined,"
          "handoffs_swept,faults_injected,faults_skipped")
    rows = run_chaos(seeds=(1, 2, 5) if fast else (1, 2, 5, 7, 9))
    for r in rows:
        print(f"{r['seed']},{r['n_done']},{r['n_retries']},"
              f"{r['n_timeouts']},{r['n_shed']},{r['blocks_quarantined']},"
              f"{r['handoffs_swept']},{r['faults_injected']},"
              f"{r['faults_skipped']}", flush=True)
    print(f"# {len(rows)} fault seeds: every request completed with greedy "
          f"output bit-identical to the fault-free baseline; "
          f"{sum(r['faults_injected'] for r in rows)} faults injected "
          f"({sum(r['blocks_quarantined'] for r in rows)} blocks "
          f"quarantined, {sum(r['n_retries'] for r in rows)} retries, "
          f"{sum(r['handoffs_swept'] for r in rows)} orphan handoffs "
          f"swept) with zero leaked blocks and zero stale summaries at "
          f"quiescence", flush=True)


# ----------------------------------------------------------------------
# Mesh ablation: the same MoE workload on the 1-device mesh vs a tp×ep
# sharded mesh (attention heads + paged KV arenas over `model`, expert
# slots over `data`), with the OmniPlacement loop live on the sharded row.
# Run with `--mesh tp,ep` under
# XLA_FLAGS=--xla_force_host_platform_device_count=<tp*ep>.
# Work-based columns are assert-gated: greedy outputs bit-identical across
# meshes, host_fetches == steps on every row, ≥ 1 live migration on the
# sharded row with the logged expert-load imbalance strictly improving.
def _mesh_workload(vocab: int, n: int):
    """Closed-loop MoE pressure: mixed lengths, a shared prefix on half the
    prompts, decode long enough (24 tokens) for the placement monitor to
    cross several activation windows mid-stream."""
    rng = np.random.default_rng(13)
    base = tuple(rng.integers(0, vocab, 24))
    reqs = []
    for i in range(n):
        if i % 2 == 0:
            reqs.append((base + tuple(rng.integers(0, vocab, 6 + i)), 24))
        else:
            reqs.append((tuple(rng.integers(0, vocab,
                                            int(rng.integers(10, 30)))), 24))
    return reqs


def run_mesh(tp: int = 2, ep: int = 4, n_requests: int = 8):
    """→ per-mesh result rows (1-device baseline, then tp×ep)."""
    import jax

    from repro.configs import reduced_config
    from repro.core.placement import SchedulerConfig
    from repro.core.proxy import OASConfig
    from repro.models import LM
    from repro.serving import DevicePlacement, Server, ServerConfig

    n_dev = tp * ep
    if jax.device_count() < n_dev:
        raise SystemExit(
            f"--mesh {tp},{ep} needs {n_dev} devices but only "
            f"{jax.device_count()} are visible — on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_dev}")

    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(
        compute_dtype="float32", param_dtype="float32")
    pl1 = DevicePlacement.local()
    lm1 = LM.build(cfg, pl1.ctx)
    params1 = lm1.init(jax.random.PRNGKey(0))

    def scfg():
        # trigger at any measurable imbalance, accept only improvements:
        # the sharded row must migrate mid-stream, and every logged move
        # must lower the simulated imbalance
        return ServerConfig(
            n_prefill=1, n_decode=1, decode_slots=4, max_len=128,
            kv_block_size=8, chunk_tokens=32, placement_interval=2,
            placement_cfg=SchedulerConfig(b_trigger=1.01, delta=0.0,
                                          window=2, ema_alpha=1.0, budget=0),
            oas=OASConfig(defer_window=0.0))

    reqs = _mesh_workload(cfg.vocab_size, n_requests)
    results, outputs = [], {}
    for name, pl in (("mesh1", pl1), (f"tp{tp}ep{ep}",
                                      DevicePlacement.build(tp=tp, ep=ep))):
        if pl is pl1:
            params = params1
        else:
            params = pl.transfer_params(lm1, params1, LM.build(cfg, pl.ctx))
        srv = Server(cfg, scfg(), placement=pl, params=params)
        s = srv.run(reqs, max_wall_s=600)
        outputs[name] = {r.rid: tuple(r.output_tokens)
                         for r in srv.metrics.done}
        ds = s["decode_stats"][0]
        assert s["n_done"] == n_requests, f"{name}: incomplete run"
        assert ds["host_fetches"] == ds["steps"], \
            f"{name}: sharding added per-step host syncs"
        for eng in srv.decodes:
            eng.pool.check_invariants()
        srv.kv_arena.check_summaries()
        log = s["migration_log"]
        results.append({
            "mesh": name, "n_done": s["n_done"],
            "tok_per_step": ds["tokens"] / max(ds["steps"], 1),
            "blocks_touched": ds["blocks_touched"],
            "host_fetches": ds["host_fetches"],
            "n_migrations": s["n_migrations"],
            "imb_before": log[0]["b_before"] if log else float("nan"),
            "imb_after": log[0]["b_after"] if log else float("nan"),
        })
    base, sharded = results
    assert outputs["mesh1"] == outputs[f"tp{tp}ep{ep}"], \
        "greedy outputs diverged between the 1-device and sharded meshes"
    assert base["n_migrations"] == 0, \
        "single-rank imbalance is 1.0 by definition — nothing to migrate"
    assert sharded["n_migrations"] >= 1, \
        "placement loop never migrated on the sharded mesh"
    assert sharded["imb_after"] < sharded["imb_before"], \
        f"migration did not improve expert-load imbalance " \
        f"({sharded['imb_before']:.3f} → {sharded['imb_after']:.3f})"
    assert sharded["tok_per_step"] == base["tok_per_step"], \
        "per-step work diverged across meshes (same schedule expected)"
    return results


def main_mesh(tp: int, ep: int, fast: bool = False):
    print("mesh,n_done,tok_per_step,blocks_touched,host_fetches,"
          "n_migrations,imb_before,imb_after")
    rows = run_mesh(tp, ep, n_requests=6 if fast else 8)
    for r in rows:
        print(f"{r['mesh']},{r['n_done']},{r['tok_per_step']:.2f},"
              f"{r['blocks_touched']},{r['host_fetches']},"
              f"{r['n_migrations']},{r['imb_before']:.3f},"
              f"{r['imb_after']:.3f}", flush=True)
    sh = rows[1]
    print(f"# greedy outputs bit-identical across meshes; the sharded row "
          f"ran {sh['n_migrations']} live expert migration(s) mid-decode, "
          f"expert-load imbalance {sh['imb_before']:.3f} → "
          f"{sh['imb_after']:.3f}, with host_fetches == decode steps on "
          f"both meshes (sharding and migration add zero per-token syncs)",
          flush=True)


def main(fast: bool = False):
    print("variant,n_done,qps,ttft_mean_s,ttft_p99_s,tpot_mean_ms,"
          "ott_tok_s,prefill_tokens,reused_tokens,prefix_hits,"
          "tok_per_step,blocks_touched,blocks_shared,blocks_fresh,"
          "host_fetches,first_fetches,prefill_kv_peak_blocks,"
          "handoff_copy_bytes,resident_bytes,admissible_slots")
    rows = run(8 if fast else 12)
    for r in rows:
        print(f"{r['variant']},{r['n_done']},{r['qps']:.2f},"
              f"{r['ttft_mean_s']:.4f},{r['ttft_p99_s']:.4f},"
              f"{r['tpot_mean_ms']:.2f},{r['ott_tok_s']:.1f},"
              f"{r['prefill_tokens']},{r['reused_tokens']},"
              f"{r['prefix_hits']},{r['tok_per_step']:.2f},"
              f"{r['blocks_touched']},{r['blocks_shared']},"
              f"{r['blocks_fresh']},{r['host_fetches']},"
              f"{r['first_fetches']},{r['prefill_kv_peak_blocks']},"
              f"{r['handoff_copy_bytes']},{r['resident_bytes']},"
              f"{r['admissible_slots']}", flush=True)
    full = next(r for r in rows if r["variant"] == "dense")
    chk = next(r for r in rows if r["variant"] == "chunked+reuse")
    dns = next(r for r in rows if r["variant"] == "chunked+reuse+dense")
    smp = next(r for r in rows if r["variant"] == "sampled")
    print(f"# greedy outputs identical across greedy variants; dense → "
          f"server defaults: ttft_mean {full['ttft_mean_s']:.4f}s"
          f" → {chk['ttft_mean_s']:.4f}s, tpot {full['tpot_mean_ms']:.1f}ms"
          f" → {chk['tpot_mean_ms']:.1f}ms; paged decode touches "
          f"{chk['blocks_touched']} KV blocks vs {dns['blocks_touched']} "
          f"slot-dense, {chk['blocks_shared']} prefix blocks mapped "
          f"(not copied); paged prefill peaks at "
          f"{chk['prefill_kv_peak_blocks']} KV blocks vs "
          f"{dns['prefill_kv_peak_blocks']} dense (∝ prompt, not max_len) "
          f"with handoff_copy_bytes={chk['handoff_copy_bytes']} (dense "
          f"scatter: {dns['handoff_copy_bytes']}); per-request sampling: "
          f"tpot {chk['tpot_mean_ms']:.1f}ms → {smp['tpot_mean_ms']:.1f}ms "
          f"with host_fetches == decode steps ({smp['host_fetches']}) — "
          f"zero per-token syncs added", flush=True)


if __name__ == "__main__":
    import sys
    # ContractGuard preamble (docs/analysis.md): every bench variant is
    # assert-gated on its serving contracts (host_fetches == steps, work
    # columns, bit-identity) — refuse to produce numbers at all on a tree
    # whose *static* contracts already fail, so a broken invariant can't
    # hide behind a plausible-looking CSV
    from repro.analysis import contract_gate
    contract_gate()
    if "--sparse" in sys.argv:
        main_sparse(fast="--fast" in sys.argv)
    elif "--quant" in sys.argv:
        main_quant(fast="--fast" in sys.argv)
    elif "--spec" in sys.argv:
        main_spec(fast="--fast" in sys.argv)
    elif "--chaos" in sys.argv:
        main_chaos(fast="--fast" in sys.argv)
    elif "--mesh" in sys.argv:
        spec = sys.argv[sys.argv.index("--mesh") + 1]
        tp, ep = (int(x) for x in spec.split(","))
        main_mesh(tp, ep, fast="--fast" in sys.argv)
    else:
        main(fast="--fast" in sys.argv)
