"""Paper Table 2 — end-to-end ablation of the three OmniInfer components.

Two arms:
  (a) cluster simulator at the paper's 6P8-1D32 configuration (Ascend model);
  (b) REAL in-process mini-engine on CPU (reduced qwen2-moe) — the same
      proxy/placement/compression code, physically executed.
"""
from __future__ import annotations

import numpy as np

from repro.sim import ClusterSim, SimConfig
from repro.sim.workload import WorkloadConfig

VARIANTS = [
    ("OmniInfer", {}),
    ("w/o OmniPlacement", dict(use_placement=False)),
    ("w/o OmniAttn", dict(use_omniattn=False)),
    ("w/o OmniProxy", dict(use_proxy=False)),
    ("w/o all", dict(use_placement=False, use_omniattn=False,
                     use_proxy=False)),
]


def run_sim(n_requests: int = 900) -> list[dict]:
    rows = []
    for name, kw in VARIANTS:
        cfg = SimConfig(n_prefill=6, decode_dies=64, batch_per_die=40,
                        concurrency=400, n_requests=n_requests,
                        workload=WorkloadConfig(seed=0), **kw)
        s = ClusterSim(cfg).run()
        rows.append({
            "variant": name, "qpm": round(s["qpm"], 1),
            "ttft_s": round(s.get("ttft_mean", np.nan), 3),
            "p99_ttft_s": round(s.get("ttft_p99", np.nan), 3),
            "tpot_ms": round(s.get("tpot_mean_ms", np.nan), 1),
            "p99_tpot_ms": round(s.get("tpot_p99_ms", np.nan), 1),
            "e2e_s": round(s.get("e2e_mean", np.nan), 2),
            "p99_e2e_s": round(s.get("e2e_p99", np.nan), 2),
            "ott_tok_s": round(s.get("ott_tok_s", np.nan)),
            "ttt_tok_s": round(s.get("ttt_tok_s", np.nan)),
            "moe_B": round(s["moe_imbalance_final"], 2),
        })
    return rows


def run_engine(n_requests: int = 6) -> list[dict]:
    """Real-engine arm (CPU, reduced MoE model, small request set)."""
    import jax
    from repro.configs import reduced_config
    from repro.core.proxy import OASConfig
    from repro.serving import Server, ServerConfig

    cfg = reduced_config("qwen2-moe-a2.7b").with_updates(n_layers=2)
    rng = np.random.default_rng(0)
    shared = tuple(rng.integers(0, 500, 16).tolist())
    reqs = []
    for i in range(n_requests):
        if i % 3 == 2 and reqs:
            reqs.append(reqs[-1])        # repeated prompt → APC hit
        elif i % 2 == 0:
            reqs.append((shared + tuple(rng.integers(0, 500, 4 + 2 * i)
                                        .tolist()), 4))
        else:
            reqs.append((tuple(rng.integers(0, 500,
                                            int(rng.integers(6, 24)))
                               .tolist()), 4))
    rows = []
    for name, oas in [("engine full", OASConfig(defer_window=0.0)),
                      ("engine w/o proxy",
                       OASConfig(defer_window=0.0, cache_aware=False,
                                 lpt=False, deferred=False))]:
        srv = Server(cfg, ServerConfig(n_prefill=2, n_decode=1,
                                       decode_slots=4, max_len=64, oas=oas))
        s = srv.run([(p, m) for p, m in reqs], max_wall_s=240)
        hits = sum(e["cache_hits"] for e in s["prefill_stats"])
        rows.append({"variant": name, "qpm": round(s["qpm"], 1),
                     "ttft_s": round(s["ttft_mean"], 3),
                     "tpot_ms": round(s["tpot_mean_ms"], 1),
                     "cache_hits": hits, "n_done": s["n_done"]})
    return rows


def main():
    print("# simulator (6P8-1D32, DeepSeek-R1-INT8 Ascend model)")
    print("variant,qpm,ttft_s,p99_ttft_s,tpot_ms,p99_tpot_ms,e2e_s,p99_e2e_s,"
          "ott_tok_s,ttt_tok_s,moe_B")
    for r in run_sim():
        print(",".join(str(r[k]) for k in
                       ("variant", "qpm", "ttft_s", "p99_ttft_s", "tpot_ms",
                        "p99_tpot_ms", "e2e_s", "p99_e2e_s", "ott_tok_s",
                        "ttt_tok_s", "moe_B")))
    print("# real mini-engine (CPU, reduced qwen2-moe)")
    print("variant,qpm,ttft_s,tpot_ms,cache_hits,n_done")
    for r in run_engine():
        print(",".join(str(r[k]) for k in
                       ("variant", "qpm", "ttft_s", "tpot_ms", "cache_hits",
                        "n_done")))


if __name__ == "__main__":
    main()
