"""Benchmark entry point: one section per paper table + kernels + roofline.
Prints ``name,us_per_call,derived``-style CSV sections."""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset: serving scaling ablation accuracy kernels "
                         "roofline")
    ap.add_argument("--fast", action="store_true",
                    help="smaller request counts / fewer steps")
    args = ap.parse_args()
    want = set(args.only) if args.only else \
        {"scaling", "ablation", "accuracy", "kernels", "roofline", "serving"}

    if "serving" in want:
        print("== bench_serving (continuous-batching ablation) ==", flush=True)
        from benchmarks import bench_serving
        bench_serving.main(fast=args.fast)

    if "kernels" in want:
        print("== bench_kernels (name,us_per_call,derived) ==", flush=True)
        from benchmarks import bench_kernels
        bench_kernels.main()

    if "scaling" in want:
        print("\n== bench_scaling (paper Table 1) ==", flush=True)
        from benchmarks import bench_scaling
        print("config,batch_per_die,qpm,ttft_s,tpot_ms")
        for r in bench_scaling.run(n_requests=300 if args.fast else 900):
            print(f"{r['config']},{r['batch_per_die']},{r['qpm']},"
                  f"{r['ttft_s']},{r['tpot_ms']}", flush=True)

    if "ablation" in want:
        print("\n== bench_ablation (paper Table 2) ==", flush=True)
        from benchmarks import bench_ablation
        for r in bench_ablation.run_sim(n_requests=300 if args.fast else 900):
            print(f"{r['variant']},qpm={r['qpm']},ttft={r['ttft_s']},"
                  f"p99ttft={r['p99_ttft_s']},tpot={r['tpot_ms']},"
                  f"B={r['moe_B']}", flush=True)
        for r in bench_ablation.run_engine(4 if args.fast else 6):
            print(f"{r['variant']},qpm={r['qpm']},ttft={r['ttft_s']},"
                  f"tpot={r['tpot_ms']},cache_hits={r['cache_hits']}",
                  flush=True)

    if "accuracy" in want:
        print("\n== bench_accuracy (paper Table 3) ==", flush=True)
        from benchmarks import bench_accuracy
        for k, v in bench_accuracy.run(80 if args.fast else 400).items():
            print(f"{k},{v}", flush=True)

    if "roofline" in want:
        print("\n== roofline (from dry-run artifacts) ==", flush=True)
        from benchmarks import roofline
        roofline.main()


if __name__ == "__main__":
    main()
