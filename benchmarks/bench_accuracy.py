"""Paper Table 3 — accuracy under OmniAttn KV compression.

CPU-scale reproduction: train a small LM on synthetic data with BOTH local
(bigram) and long-range (copy at distance 64 > sink+recent window) structure,
then measure retrieval accuracy with (a) full KV, (b) everything compressed,
(c) the GA-searched layer pattern. The GA must discover that keeping SOME
layers uncompressed preserves retrieval (the paper's layer-wise thesis) while
still cutting KV bytes — plus eq. 5 attention-fidelity metrics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.omniattn import (GAConfig, PatternSearch, attention_fidelity,
                                 block_subset_indices)
from repro.models import LM
from repro.training.data import DataConfig, make_batch, synth_tokens
from repro.training.optim import adamw_init
from repro.training.trainer import make_train_step


def train_small_lm(steps: int = 150, seed: int = 0):
    cfg = reduced_config("qwen2-1.5b").with_updates(
        n_layers=4, omniattn=reduced_config("qwen2-1.5b").omniattn)
    from dataclasses import replace
    cfg = replace(cfg, omniattn=replace(cfg.omniattn, sink_tokens=4,
                                        recent_tokens=24))
    mesh = __import__("repro.distributed.ctx", fromlist=["local_mesh_ctx"]) \
        .local_mesh_ctx()
    lm = LM.build(cfg, mesh, pattern=[0] * cfg.n_layers)
    base_plan = lm.plan
    params = lm.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params, cfg.optimizer_dtype)
    step = jax.jit(make_train_step(lm, lr=2e-3))
    dcfg = DataConfig(cfg.vocab_size, 96, 8, seed=seed, copy_dist=64,
                      copy_prob=0.35)
    for i in range(steps):
        params, opt, m = step(params, opt, make_batch(cfg, dcfg, i), None)
    return cfg, mesh, params, dcfg, float(m["loss"]), base_plan


def eval_accuracy(cfg, mesh, params, dcfg, pattern, n_eval: int = 4,
                  base_plan=None) -> float:
    """Decode-path next-token accuracy at positions past the window: prefill
    S tokens through the (possibly compressed) cache, predict token S."""
    from repro.models.stack import regroup_params
    lm = LM.build(cfg, mesh, pattern=list(pattern))
    if base_plan is not None and base_plan != lm.plan:
        params = dict(params, stack=regroup_params(params["stack"], base_plan,
                                                   lm.plan))
    d = 64
    correct = total = 0
    for i in range(n_eval):
        toks = np.asarray(synth_tokens(
            DataConfig(cfg.vocab_size, 96, 4, seed=1000 + i, copy_dist=d,
                       copy_prob=0.35), 0))
        # force the final prediction to be a long-range copy: marker token,
        # then the DECODE step must retrieve t[i-d] through the (possibly
        # compressed) cache — the path OmniAttn actually changes.
        S = toks.shape[1] - 1            # prefill length
        # marker decodes at position S → the prediction is position S+1,
        # whose copy source is position S+1-d
        target = toks[np.arange(toks.shape[0]), S + 1 - d].copy()
        ctx = jnp.asarray(toks[:, :S])
        cache, _, _ = lm.prefill(params, {"tokens": ctx}, max_len=S + 4)
        marker = jnp.zeros((toks.shape[0], 1), jnp.int32)
        _, logits, _ = lm.decode(params, cache, marker, jnp.int32(S))
        pred = jnp.argmax(logits, -1)
        correct += int((pred == jnp.asarray(target)).sum())
        total += int(target.shape[0])
    return correct / max(total, 1)


def quant_fidelity(q, k, v, bs, selected_mass, lens):
    """QuantPlane fidelity on the same proxy, through the production arena
    helpers the int8 plane actually runs (models/attention.py): per-token
    provisional quantization (`quant_tokens`), seal-on-full re-quantization
    (`seal_blocks`), the elementwise dequant rule (`dequant_pages`), and
    summary maintenance on DEQUANTIZED content (`update_block_summaries`
    with the scale plane). Reports the per-block round-trip error, the
    full-cache attention output/mass deltas, and the top-k kept mass when
    the Quest summaries are reduced from the int8 arena + scale plane."""
    from repro.models.attention import (block_topk_scores, dequant_pages,
                                        quant_tokens, seal_blocks,
                                        update_block_summaries)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    q = jnp.asarray(q, jnp.float32)
    M, d = k.shape
    nb = M // bs

    def roundtrip(x):
        # [1 + nb, K=1, bs, d] arena with the null block 0 prepended; seal
        # every real block but the last, which stays in the per-token
        # provisional tail format — both dequant branches are exercised
        pages = jnp.concatenate(
            [jnp.zeros((1, bs, d), jnp.float32),
             x.reshape(nb, bs, d)])[:, None]
        q8, tok = quant_tokens(pages)
        scale = jnp.zeros((nb + 1, 1, d), jnp.float32)
        blocks = jnp.arange(1, nb + 1)
        q8, scale, tok = seal_blocks(q8, scale, tok, blocks, blocks < nb)
        deq = dequant_pages(q8, scale, tok)[1:, 0].reshape(M, d)
        return q8, scale, tok, deq

    kq8, kscale, ktok, kd = roundtrip(k)
    _, _, _, vd = roundtrip(v)

    def per_block(orig, deq):
        o = orig.reshape(nb, -1)
        return jnp.linalg.norm(deq.reshape(nb, -1) - o, axis=-1) \
            / jnp.maximum(jnp.linalg.norm(o, axis=-1), 1e-9)

    errs = jnp.concatenate([per_block(k, kd), per_block(v, vd)])

    sc = d ** -0.5
    p = jax.nn.softmax((q @ k.T) * sc, axis=-1)
    pq = jax.nn.softmax((q @ kd.T) * sc, axis=-1)
    ref = p @ v
    out_rel = jnp.linalg.norm(pq @ vd - ref) \
        / jnp.maximum(jnp.linalg.norm(ref), 1e-9)
    # total-variation distance between the f32 and dequantized attention
    # distributions — how much probability mass quantization moved
    mass_delta = jnp.abs(pq - p).sum(-1).mean() / 2.0

    zero = jnp.zeros((nb + 1, 1, d), jnp.float32)
    kmin_q, kmax_q, _ = update_block_summaries(
        zero, zero, zero, kq8, jnp.arange(nb + 1),
        k_scale=kscale, k_tok=ktok)
    tables_q = (jnp.arange(nb) + 1)[None]
    topk_q = selected_mass(block_topk_scores(
        q[None], kmin_q, kmax_q, tables_q, lens, block_size=bs))
    return {
        "quant_block_rel_err_mean": round(float(errs.mean()), 4),
        "quant_block_rel_err_max": round(float(errs.max()), 4),
        "quant_attn_out_rel_err": round(float(out_rel), 4),
        "quant_attn_mass_delta": round(float(mass_delta), 4),
        "topk_quant_attn_mass_kept": round(topk_q["attn_mass"], 4),
        "topk_quant_rel_err": round(topk_q["rel_err"], 4),
    }


def quant_greedy_gate(cfg, params, n_requests: int = 4):
    """Serve the TRAINED model greedily through f32 and int8 paged arenas
    and assert token-stream equality — with the int8 pool sized to the f32
    row's HBM byte budget (more blocks, same bytes), so the gate covers
    exactly the configuration the residency win runs at."""
    from repro.core.proxy import OASConfig
    from repro.serving import Server, ServerConfig
    from repro.serving.quant import QuantConfig

    def build(quant, kv_blocks):
        scfg = ServerConfig(
            n_prefill=1, n_decode=1, decode_slots=2, max_len=64,
            chunk_tokens=32, prefill_tick_budget=64, prefix_reuse=False,
            paged_kv=True, kv_blocks=int(kv_blocks), kv_block_size=16,
            quant=QuantConfig() if quant else None,
            oas=OASConfig(defer_window=0.0))
        return Server(cfg, scfg, pattern=[0] * cfg.n_layers, params=params)

    rng = np.random.default_rng(7)
    reqs = [(tuple(int(t) for t in
                   rng.integers(1, cfg.vocab_size, 24 + 8 * i)), 6)
            for i in range(n_requests)]

    f32 = build(False, 16)
    f32.run(list(reqs))
    ref = {r.rid: tuple(r.output_tokens) for r in f32.metrics.done}
    assert len(ref) == n_requests and all(len(t) == 6 for t in ref.values())
    n_f32 = f32.kv_arena.pool.n_blocks
    budget = n_f32 * f32.kv_arena.block_nbytes

    probe = build(True, 16)          # read the int8 block size, then
    q8 = build(True, budget // probe.kv_arena.block_nbytes)   # re-spend
    assert q8.kv_arena.quant and q8.kv_arena.pool.n_blocks > n_f32
    q8.run(list(reqs))
    got = {r.rid: tuple(r.output_tokens) for r in q8.metrics.done}
    assert got == ref, \
        "int8 greedy decode diverged from f32 on the trained model"
    q8.kv_arena.check_summaries()
    return {
        "quant_greedy_equal": int(got == ref),
        "quant_budget_blocks_f32": n_f32,
        "quant_budget_blocks_int8": q8.kv_arena.pool.n_blocks,
    }


def run(steps: int = 400):
    cfg, mesh, params, dcfg, loss, base_plan = train_small_lm(steps)
    base = eval_accuracy(cfg, mesh, params, dcfg, [0] * cfg.n_layers,
                         base_plan=base_plan)
    default_pat = cfg.default_compression_pattern()
    comp = eval_accuracy(cfg, mesh, params, dcfg, default_pat,
                         base_plan=base_plan)
    all_comp = eval_accuracy(cfg, mesh, params, dcfg, [1] * cfg.n_layers,
                             base_plan=base_plan)

    search = PatternSearch(
        cfg, lambda p: eval_accuracy(cfg, mesh, params, dcfg, p,
                                     base_plan=base_plan),
        GAConfig(population=8, generations=6, accuracy_tau=0.97, seed=0),
        seq_len=96)
    ga = search.run()

    # eq.5 attention fidelity on the trained model's scale-free proxy
    rng = jax.random.PRNGKey(0)
    r1, r2, r3 = jax.random.split(rng, 3)
    M, d = 256, 32
    k = jax.random.normal(r2, (M, d)) * 0.05   # sink-concentrated attention
    k = k.at[:4].add(2.0)
    k = k.at[-24:].add(1.0)
    v = jax.random.normal(r3, (M, d))
    q = jax.random.normal(r1, (8, d)) + k[:4].mean(0) * 0.5
    fid = attention_fidelity(q, k, v, cfg.omniattn.sink_tokens,
                             cfg.omniattn.recent_tokens)

    # ONLINE top-k block selection on the same proxy: summarize the keys
    # into per-block channel bounds, score with the Quest upper bound, keep
    # a 50% block budget (sink + recent blocks forced), and report the
    # attention mass / output error of exactly the token subset the paged
    # decode path would attend — the dynamic counterpart of the static
    # sink+recent figure above, through the production helpers.
    from repro.models.attention import (block_topk_scores, select_kv_blocks,
                                        update_block_summaries)
    bs = 16
    nb = M // bs
    k_pages = jnp.asarray(k).reshape(nb, bs, 1, d).transpose(0, 2, 1, 3)
    summ = [jnp.zeros((nb, 1, d), jnp.float32) for _ in range(3)]
    kmin, kmax, kmean = update_block_summaries(*summ, k_pages,
                                               jnp.arange(nb))
    tables = jnp.arange(nb)[None]
    lens = jnp.asarray([M])

    def selected_mass(scores):
        _, _, _, selected = select_kv_blocks(
            scores, tables, lens, block_size=bs, k_static=nb // 2, frac=0.0,
            sink_blocks=1, recent_blocks=2)
        idx = block_subset_indices(M, np.flatnonzero(np.asarray(selected[0])),
                                   bs)
        return attention_fidelity(q, k, v, indices=idx)

    topk_fid = selected_mass(block_topk_scores(
        jnp.asarray(q)[None], kmin, kmax, tables, lens, block_size=bs))
    # scoring ablation: rank blocks by query · block-center (the kmean
    # summary, InfLLM-style) instead of the min/max upper bound — the
    # center ranking has no no-false-negative guarantee for the argmax
    # block, which is what the bound buys
    center = jnp.einsum("qd,nd->qn", jnp.asarray(q), kmean[:, 0]).max(0)
    mean_fid = selected_mass(jnp.broadcast_to(center, (1, nb)))

    # QuantPlane fidelity: the same proxy round-tripped through the int8
    # arena format + the trained model served greedily through f32 and
    # int8 arenas at a matched HBM budget (the bit-identity gate)
    qf = quant_fidelity(q, k, v, bs, selected_mass, lens)
    assert qf["quant_block_rel_err_max"] < 0.05, \
        f"int8 round-trip error {qf['quant_block_rel_err_max']} — the " \
        f"per-block/per-token scale plane is mis-scaled"
    assert abs(qf["topk_quant_attn_mass_kept"]
               - topk_fid["attn_mass"]) < 0.02, \
        "quantized summaries shifted the top-k kept mass"
    qf.update(quant_greedy_gate(cfg, params))

    return {
        "train_loss": round(loss, 3),
        "acc_full_kv": round(base, 4),
        "acc_default_pattern": round(comp, 4),
        "acc_all_compressed": round(all_comp, 4),
        "acc_ga_pattern": round(ga["accuracy"], 4),
        "ga_kv_gain": round(ga["kv_gain"], 3),
        "ga_feasible": ga["feasible"],
        "fidelity_rel_err": round(fid["rel_err"], 4),
        "fidelity_attn_mass": round(fid["attn_mass"], 4),
        "topk_rel_err": round(topk_fid["rel_err"], 4),
        "topk_attn_mass_kept": round(topk_fid["attn_mass"], 4),
        "topk_mean_score_attn_mass": round(mean_fid["attn_mass"], 4),
        **qf,
    }


def main():
    r = run()
    print("metric,value")
    for k, v in r.items():
        print(f"{k},{v}")


if __name__ == "__main__":
    main()
