"""Paper Table 3 — accuracy under OmniAttn KV compression.

CPU-scale reproduction: train a small LM on synthetic data with BOTH local
(bigram) and long-range (copy at distance 64 > sink+recent window) structure,
then measure retrieval accuracy with (a) full KV, (b) everything compressed,
(c) the GA-searched layer pattern. The GA must discover that keeping SOME
layers uncompressed preserves retrieval (the paper's layer-wise thesis) while
still cutting KV bytes — plus eq. 5 attention-fidelity metrics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core.omniattn import (GAConfig, PatternSearch, attention_fidelity,
                                 block_subset_indices)
from repro.models import LM
from repro.training.data import DataConfig, make_batch, synth_tokens
from repro.training.optim import adamw_init
from repro.training.trainer import make_train_step


def train_small_lm(steps: int = 150, seed: int = 0):
    cfg = reduced_config("qwen2-1.5b").with_updates(
        n_layers=4, omniattn=reduced_config("qwen2-1.5b").omniattn)
    from dataclasses import replace
    cfg = replace(cfg, omniattn=replace(cfg.omniattn, sink_tokens=4,
                                        recent_tokens=24))
    mesh = __import__("repro.distributed.ctx", fromlist=["local_mesh_ctx"]) \
        .local_mesh_ctx()
    lm = LM.build(cfg, mesh, pattern=[0] * cfg.n_layers)
    base_plan = lm.plan
    params = lm.init(jax.random.PRNGKey(seed))
    opt = adamw_init(params, cfg.optimizer_dtype)
    step = jax.jit(make_train_step(lm, lr=2e-3))
    dcfg = DataConfig(cfg.vocab_size, 96, 8, seed=seed, copy_dist=64,
                      copy_prob=0.35)
    for i in range(steps):
        params, opt, m = step(params, opt, make_batch(cfg, dcfg, i), None)
    return cfg, mesh, params, dcfg, float(m["loss"]), base_plan


def eval_accuracy(cfg, mesh, params, dcfg, pattern, n_eval: int = 4,
                  base_plan=None) -> float:
    """Decode-path next-token accuracy at positions past the window: prefill
    S tokens through the (possibly compressed) cache, predict token S."""
    from repro.models.stack import regroup_params
    lm = LM.build(cfg, mesh, pattern=list(pattern))
    if base_plan is not None and base_plan != lm.plan:
        params = dict(params, stack=regroup_params(params["stack"], base_plan,
                                                   lm.plan))
    d = 64
    correct = total = 0
    for i in range(n_eval):
        toks = np.asarray(synth_tokens(
            DataConfig(cfg.vocab_size, 96, 4, seed=1000 + i, copy_dist=d,
                       copy_prob=0.35), 0))
        # force the final prediction to be a long-range copy: marker token,
        # then the DECODE step must retrieve t[i-d] through the (possibly
        # compressed) cache — the path OmniAttn actually changes.
        S = toks.shape[1] - 1            # prefill length
        # marker decodes at position S → the prediction is position S+1,
        # whose copy source is position S+1-d
        target = toks[np.arange(toks.shape[0]), S + 1 - d].copy()
        ctx = jnp.asarray(toks[:, :S])
        cache, _, _ = lm.prefill(params, {"tokens": ctx}, max_len=S + 4)
        marker = jnp.zeros((toks.shape[0], 1), jnp.int32)
        _, logits, _ = lm.decode(params, cache, marker, jnp.int32(S))
        pred = jnp.argmax(logits, -1)
        correct += int((pred == jnp.asarray(target)).sum())
        total += int(target.shape[0])
    return correct / max(total, 1)


def run(steps: int = 400):
    cfg, mesh, params, dcfg, loss, base_plan = train_small_lm(steps)
    base = eval_accuracy(cfg, mesh, params, dcfg, [0] * cfg.n_layers,
                         base_plan=base_plan)
    default_pat = cfg.default_compression_pattern()
    comp = eval_accuracy(cfg, mesh, params, dcfg, default_pat,
                         base_plan=base_plan)
    all_comp = eval_accuracy(cfg, mesh, params, dcfg, [1] * cfg.n_layers,
                             base_plan=base_plan)

    search = PatternSearch(
        cfg, lambda p: eval_accuracy(cfg, mesh, params, dcfg, p,
                                     base_plan=base_plan),
        GAConfig(population=8, generations=6, accuracy_tau=0.97, seed=0),
        seq_len=96)
    ga = search.run()

    # eq.5 attention fidelity on the trained model's scale-free proxy
    rng = jax.random.PRNGKey(0)
    r1, r2, r3 = jax.random.split(rng, 3)
    M, d = 256, 32
    k = jax.random.normal(r2, (M, d)) * 0.05   # sink-concentrated attention
    k = k.at[:4].add(2.0)
    k = k.at[-24:].add(1.0)
    v = jax.random.normal(r3, (M, d))
    q = jax.random.normal(r1, (8, d)) + k[:4].mean(0) * 0.5
    fid = attention_fidelity(q, k, v, cfg.omniattn.sink_tokens,
                             cfg.omniattn.recent_tokens)

    # ONLINE top-k block selection on the same proxy: summarize the keys
    # into per-block channel bounds, score with the Quest upper bound, keep
    # a 50% block budget (sink + recent blocks forced), and report the
    # attention mass / output error of exactly the token subset the paged
    # decode path would attend — the dynamic counterpart of the static
    # sink+recent figure above, through the production helpers.
    from repro.models.attention import (block_topk_scores, select_kv_blocks,
                                        update_block_summaries)
    bs = 16
    nb = M // bs
    k_pages = jnp.asarray(k).reshape(nb, bs, 1, d).transpose(0, 2, 1, 3)
    summ = [jnp.zeros((nb, 1, d), jnp.float32) for _ in range(3)]
    kmin, kmax, kmean = update_block_summaries(*summ, k_pages,
                                               jnp.arange(nb))
    tables = jnp.arange(nb)[None]
    lens = jnp.asarray([M])

    def selected_mass(scores):
        _, _, _, selected = select_kv_blocks(
            scores, tables, lens, block_size=bs, k_static=nb // 2, frac=0.0,
            sink_blocks=1, recent_blocks=2)
        idx = block_subset_indices(M, np.flatnonzero(np.asarray(selected[0])),
                                   bs)
        return attention_fidelity(q, k, v, indices=idx)

    topk_fid = selected_mass(block_topk_scores(
        jnp.asarray(q)[None], kmin, kmax, tables, lens, block_size=bs))
    # scoring ablation: rank blocks by query · block-center (the kmean
    # summary, InfLLM-style) instead of the min/max upper bound — the
    # center ranking has no no-false-negative guarantee for the argmax
    # block, which is what the bound buys
    center = jnp.einsum("qd,nd->qn", jnp.asarray(q), kmean[:, 0]).max(0)
    mean_fid = selected_mass(jnp.broadcast_to(center, (1, nb)))

    return {
        "train_loss": round(loss, 3),
        "acc_full_kv": round(base, 4),
        "acc_default_pattern": round(comp, 4),
        "acc_all_compressed": round(all_comp, 4),
        "acc_ga_pattern": round(ga["accuracy"], 4),
        "ga_kv_gain": round(ga["kv_gain"], 3),
        "ga_feasible": ga["feasible"],
        "fidelity_rel_err": round(fid["rel_err"], 4),
        "fidelity_attn_mass": round(fid["attn_mass"], 4),
        "topk_rel_err": round(topk_fid["rel_err"], 4),
        "topk_attn_mass_kept": round(topk_fid["attn_mass"], 4),
        "topk_mean_score_attn_mass": round(mean_fid["attn_mass"], 4),
    }


def main():
    r = run()
    print("metric,value")
    for k, v in r.items():
        print(f"{k},{v}")


if __name__ == "__main__":
    main()
