"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape × mesh): the three roofline terms
  compute   = HLO_FLOPs_per_device / peak_FLOP/s
  memory    = HLO_bytes_per_device / HBM_bw
  collective= collective_bytes_per_device / link_bw
(dividing per-device quantities by per-chip rates ≡ the brief's global/chips
formulation), the dominant term, MODEL_FLOPS/HLO_FLOPS utilization, and one
actionable sentence per cell.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _advice(rec) -> str:
    r = rec["roofline"]
    dom = r["dominant"]
    coll = rec["collective_bytes_per_device"]
    if dom == "memory_s":
        if rec["kind"] == "decode":
            return ("KV/weight reads dominate: widen OmniAttn compression, "
                    "int8 weights, or larger per-step batch")
        return ("activation traffic dominates: fuse norms/rope into matmuls, "
                "bf16 intermediates, larger attention chunks")
    if dom == "compute_s":
        if rec.get("useful_flops_ratio") and rec["useful_flops_ratio"] < 0.7:
            return "recompute/padding waste: relax remat policy or pad less"
        return "near compute roofline: only algorithmic sparsity helps"
    big = max((k for k in ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute")),
              key=lambda k: coll[k])
    return f"collective-bound ({big}): reshard to cut {big} volume or overlap"


def load(mesh: str, include_tags: bool = False) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(str(RESULTS / mesh / "*.json"))):
        r = json.loads(Path(f).read_text())
        if r.get("tag") and not include_tags:
            continue               # §Perf hillclimb variants live separately
        rows.append(r)
    return rows


def table(mesh: str = "pod_16x16") -> list[dict]:
    out = []
    for rec in load(mesh):
        if rec["status"] != "ok":
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "status": rec["status"],
                        "reason": rec.get("reason", rec.get("error", ""))})
            continue
        t = rec["roofline"]["terms"]
        bound = max(t.values())
        out.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "dominant": rec["roofline"]["dominant"].replace("_s", ""),
            "roofline_frac": (t["compute_s"] / bound) if bound else 0.0,
            "model_flops": rec["model_flops_total"],
            "hlo_flops": rec["hlo_flops_total"],
            "useful_ratio": rec.get("useful_flops_ratio"),
            "advice": _advice(rec),
        })
    return out


def main():
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        if not (RESULTS / mesh).exists():
            continue
        print(f"# roofline — {mesh}")
        print("arch,shape,compute_s,memory_s,collective_s,dominant,"
              "useful_flops_ratio,advice")
        for r in table(mesh):
            if r["status"] != "ok":
                print(f"{r['arch']},{r['shape']},-,-,-,{r['status']},-,"
                      f"{r['reason'][:60]}")
                continue
            ur = f"{r['useful_ratio']:.3f}" if r["useful_ratio"] else "-"
            print(f"{r['arch']},{r['shape']},{r['compute_s']:.5f},"
                  f"{r['memory_s']:.5f},{r['collective_s']:.5f},"
                  f"{r['dominant']},{ur},{r['advice']}")


if __name__ == "__main__":
    main()
