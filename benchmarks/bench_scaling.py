"""Paper Table 1 — scaling under different xPyD configurations.

Runs the cluster simulator (real OmniProxy/OmniPlacement policies, calibrated
Ascend-910C model) across the paper's configurations and batch sizes.
"""
from __future__ import annotations

from repro.sim import ClusterSim, SimConfig
from repro.sim.workload import WorkloadConfig

# (label, n_prefill, decode_dies, per-die batch)
CONFIGS = [
    ("4P8-1D32", 4, 64, 24),
    ("5P8-1D32", 5, 64, 30),
    ("5P8-1D32", 5, 64, 32),
    ("6P8-1D32", 6, 64, 40),
    ("6P8-1D32", 6, 64, 44),
    ("6P8-1D32", 6, 64, 46),
    ("6P8-1D32", 6, 64, 48),
    ("8P8-1D64", 8, 128, 24),
]


def run(n_requests: int = 900) -> list[dict]:
    rows = []
    for label, n_p, dies, bpd in CONFIGS:
        # paper-style concurrency: scaled with system batch, bounded so the
        # prefill side stays feasible (see EXPERIMENTS.md §Table-1 notes)
        conc = min(bpd * dies // 4, 900)
        cfg = SimConfig(n_prefill=n_p, decode_dies=dies, batch_per_die=bpd,
                        concurrency=conc, n_requests=n_requests,
                        workload=WorkloadConfig(seed=0))
        s = ClusterSim(cfg).run()
        rows.append({"config": label, "batch_per_die": bpd,
                     "qpm": round(s["qpm"], 1),
                     "ttft_s": round(s.get("ttft_mean", float("nan")), 3),
                     "tpot_ms": round(s.get("tpot_mean_ms", float("nan")), 1)})
    return rows


def main():
    print("config,batch_per_die,qpm,ttft_s,tpot_ms")
    for r in run():
        print(f"{r['config']},{r['batch_per_die']},{r['qpm']},{r['ttft_s']},"
              f"{r['tpot_ms']}")


if __name__ == "__main__":
    main()
