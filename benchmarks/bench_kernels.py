"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-grade
timing only) vs the jnp reference path, plus the chunked-attention XLA path.
On TPU the same harness times the compiled kernels."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(f, *args, n=3):
    f(*args)                                   # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    rows = []
    rng = jax.random.PRNGKey(0)
    r = jax.random.split(rng, 8)

    B, S, H, K, h = 1, 1024, 8, 2, 64
    q = jax.random.normal(r[0], (B, S, H, h), jnp.float32)
    k = jax.random.normal(r[1], (B, S, K, h), jnp.float32)
    v = jax.random.normal(r[2], (B, S, K, h), jnp.float32)
    qf = jnp.repeat(q, 1, 2).transpose(0, 2, 1, 3).reshape(B * H, S, h)
    kf = jnp.repeat(k, H // K, 2).transpose(0, 2, 1, 3).reshape(B * H, S, h)
    vf = jnp.repeat(v, H // K, 2).transpose(0, 2, 1, 3).reshape(B * H, S, h)
    rows.append(("flash_prefill_ref_jnp_1k",
                 _time(jax.jit(lambda a, b, c: ref.flash_prefill_ref(a, b, c)),
                       qf, kf, vf), "dense softmax"))
    rows.append(("flash_prefill_pallas_interp_1k",
                 _time(lambda a, b, c: ops.attention_prefill_op(a, b, c),
                       q, k, v, n=1), "interpret-mode (correctness timing)"))

    W, G = 4224, 4
    qd = jax.random.normal(r[3], (4, K, G, h), jnp.float32)
    kc = jax.random.normal(r[4], (4, K, W, h), jnp.float32)
    vc = jax.random.normal(r[5], (4, K, W, h), jnp.float32)
    t = jnp.full((4,), W, jnp.int32)
    rows.append(("sink_decode_ref_jnp_w4224",
                 _time(jax.jit(ref.sink_decode_ref), qd, kc, vc, t),
                 "compressed-cache decode"))

    s_, C, D, F = 8, 512, 256, 512
    x = jax.random.normal(r[6], (s_, C, D), jnp.float32)
    w = jax.random.normal(r[7], (s_, D, F), jnp.float32)
    nv = jnp.full((s_,), C, jnp.int32)
    rows.append(("moe_gmm_ref_jnp",
                 _time(jax.jit(ref.moe_gmm_ref), x, w, nv), "slot bmm"))
    return rows


def main():
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
